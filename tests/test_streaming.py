"""Streaming aggregation layer: chunked == one-shot, merge associativity,
incremental combination interning, region-tiled Pallas kernel, and the
profiler/serve streaming wiring."""

import time

import numpy as np
import pytest

from repro.core.estimator import (aggregate_samples_np, encode_combinations,
                                  estimate_combinations, estimate_regions,
                                  estimates_from_statistics)
from repro.core.profiler import EnergyProfiler
from repro.core.streaming import (CombinationInterner, StreamingAggregator,
                                  StreamingCombinationAggregator,
                                  stream_estimate)
from repro.core.timeline import RegionCost, ground_truth, synthesize


def _stream(n=20000, R=37, seed=0, int_powers=False):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, R, n).astype(np.int32)
    if int_powers:
        pows = rng.integers(0, 200, n).astype(np.float64)
    else:
        pows = 50.0 + 150.0 * rng.random(n)
    return ids, pows


# ---------------------------------------------------------------------------
# StreamingAggregator
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [1, 7, 1000, 4096, 10**9])
def test_chunked_matches_oneshot_exact(chunk):
    """Integer-valued powers: chunked accumulation is bit-exact (all
    partial sums representable), so counts/Σpow/Σpow² match to the ULP."""
    ids, pows = _stream(5000, 37, int_powers=True)
    ref = aggregate_samples_np(ids, pows, 37)
    agg = StreamingAggregator(37)
    for lo in range(0, len(ids), chunk):
        agg.update(ids[lo:lo + chunk], pows[lo:lo + chunk])
    for got, want in zip(agg.statistics(), ref):
        np.testing.assert_array_equal(got, want)
    assert agg.n_total == 5000


def test_chunked_matches_oneshot_float():
    ids, pows = _stream(30000, 64, seed=3)
    ref = aggregate_samples_np(ids, pows, 64)
    agg = StreamingAggregator(64)
    agg.update_stream((ids[lo:lo + 999], pows[lo:lo + 999])
                      for lo in range(0, len(ids), 999))
    counts, psum, psumsq = agg.statistics()
    np.testing.assert_array_equal(counts, ref[0])
    np.testing.assert_allclose(psum, ref[1], rtol=1e-12)
    np.testing.assert_allclose(psumsq, ref[2], rtol=1e-12)


def test_merge_associative_across_shards():
    ids, pows = _stream(9000, 16, int_powers=True)
    ref = aggregate_samples_np(ids, pows, 16)
    cuts = [(0, 2500), (2500, 6000), (6000, 9000)]
    shards = [StreamingAggregator(16).update(ids[a:b], pows[a:b])
              for a, b in cuts]

    left = StreamingAggregator(16)
    left.merge(shards[0]).merge(shards[1]).merge(shards[2])
    right = StreamingAggregator(16)
    right.merge(shards[2]).merge(shards[0]).merge(shards[1])
    for l, r, w in zip(left.statistics(), right.statistics(), ref):
        np.testing.assert_array_equal(l, r)
        np.testing.assert_array_equal(l, w)


def test_merge_grows_region_space():
    a = StreamingAggregator(4).update([0, 3], [1.0, 2.0])
    b = StreamingAggregator(8).update([7], [5.0])
    a.merge(b)
    assert a.num_regions == 8
    assert a.counts[7] == 1 and a.counts[0] == 1
    with pytest.raises(ValueError):
        a.grow(2)


def test_streaming_estimates_equal_oneshot():
    ids, pows = _stream(12000, 8, seed=9)
    names = [f"r{i}" for i in range(8)]
    est_one = estimate_regions(ids, pows, 6.0, names)
    est_stream = stream_estimate(
        ((ids[lo:lo + 1024], pows[lo:lo + 1024])
         for lo in range(0, len(ids), 1024)), 6.0, names)
    assert est_stream.n_total == est_one.n_total
    for a, b in zip(est_stream.regions, est_one.regions):
        assert a.n_samples == b.n_samples
        assert a.e_hat == pytest.approx(b.e_hat, rel=1e-12)
        assert a.t_lo == pytest.approx(b.t_lo, rel=1e-12)


# ---------------------------------------------------------------------------
# Combination interning
# ---------------------------------------------------------------------------

def test_interner_matches_np_unique_ordering_independently():
    rng = np.random.default_rng(7)
    mat = rng.integers(0, 4, (6000, 3))
    one_ids, one_combos = encode_combinations(mat)

    interner = CombinationInterner()
    parts = [interner.encode(mat[lo:lo + 1111])
             for lo in range(0, len(mat), 1111)]
    s_ids = np.concatenate(parts)
    s_combos = interner.combos

    # Same combination set; every sample maps to the same tuple.
    assert set(s_combos) == set(one_combos)
    for i in range(0, len(mat), 517):
        assert s_combos[s_ids[i]] == one_combos[one_ids[i]] == tuple(mat[i])
    # Id spaces are consistent bijections of each other.
    remap = {}
    for sid, oid in zip(s_ids, one_ids):
        assert remap.setdefault(int(sid), int(oid)) == int(oid)


def test_interner_rejects_width_change():
    interner = CombinationInterner()
    interner.encode(np.zeros((4, 2), np.int64))
    with pytest.raises(ValueError):
        interner.encode(np.zeros((4, 3), np.int64))


def test_streaming_combinations_equal_oneshot():
    rng = np.random.default_rng(11)
    mat = rng.integers(0, 3, (8000, 2))
    pows = rng.integers(40, 120, 8000).astype(np.float64)
    names = ["a", "b", "c"]
    est_one, combos_one = estimate_combinations(mat, pows, 12.0, names)

    agg = StreamingCombinationAggregator()
    agg.update_stream((mat[lo:lo + 700], pows[lo:lo + 700])
                      for lo in range(0, len(mat), 700))
    est_s, combos_s = agg.estimates(12.0, names)
    assert set(combos_s) == set(combos_one)
    by_s, by_one = est_s.by_name(), est_one.by_name()
    assert set(by_s) == set(by_one)
    for k in by_s:
        assert by_s[k].n_samples == by_one[k].n_samples
        assert by_s[k].e_hat == pytest.approx(by_one[k].e_hat, rel=1e-12)


def test_streaming_combination_merge():
    rng = np.random.default_rng(13)
    mat = rng.integers(0, 3, (6000, 2))
    pows = rng.integers(40, 120, 6000).astype(np.float64)
    whole = StreamingCombinationAggregator().update(mat, pows)
    sharded = StreamingCombinationAggregator()
    for a, b in [(0, 1500), (1500, 4000), (4000, 6000)]:
        sharded.merge(
            StreamingCombinationAggregator().update(mat[a:b], pows[a:b]))
    est_w, _ = whole.estimates(5.0, ["a", "b", "c"])
    est_s, _ = sharded.estimates(5.0, ["a", "b", "c"])
    by_w, by_s = est_w.by_name(), est_s.by_name()
    assert set(by_w) == set(by_s)
    for k in by_w:
        assert by_w[k].n_samples == by_s[k].n_samples
        assert by_w[k].e_hat == pytest.approx(by_s[k].e_hat, rel=1e-12)


# ---------------------------------------------------------------------------
# Region-tiled Pallas kernel (interpret mode)
# ---------------------------------------------------------------------------

def test_pallas_region_tiled_r8192_exact():
    """R > 2048 exercises the region-tile grid axis; integer powers at
    f32-exact magnitudes make the comparison bit-exact."""
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels.sample_attr.sample_attr import sample_attr_pallas
    rng = np.random.default_rng(17)
    n, R = 4096 + 33, 8192
    ids = rng.integers(0, R, n).astype(np.int32)
    pows = rng.integers(0, 100, n).astype(np.float32)
    c, s, sq = sample_attr_pallas(jnp.asarray(ids), jnp.asarray(pows), R,
                                  interpret=True)
    cr, sr, sqr = aggregate_samples_np(ids, pows.astype(np.float64), R)
    np.testing.assert_array_equal(np.asarray(c, np.int64), cr)
    np.testing.assert_array_equal(np.asarray(s, np.float64), sr)
    np.testing.assert_array_equal(np.asarray(sq, np.float64), sqr)


@pytest.mark.parametrize("R,block_r", [(2500, 1024), (130, 64), (8192, 4096)])
def test_pallas_region_tiling_padding(R, block_r):
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels.sample_attr.sample_attr import sample_attr_pallas
    rng = np.random.default_rng(R)
    ids = rng.integers(0, R, 3000).astype(np.int32)
    pows = rng.integers(0, 50, 3000).astype(np.float32)
    c, s, sq = sample_attr_pallas(jnp.asarray(ids), jnp.asarray(pows), R,
                                  block_r=block_r, interpret=True)
    assert c.shape == (R,)
    cr, sr, sqr = aggregate_samples_np(ids, pows.astype(np.float64), R)
    np.testing.assert_array_equal(np.asarray(c, np.int64), cr)
    np.testing.assert_array_equal(np.asarray(s, np.float64), sr)
    np.testing.assert_array_equal(np.asarray(sq, np.float64), sqr)


def test_streaming_with_pallas_chunked_aggregate_fn():
    from repro.kernels.sample_attr.ops import chunked_aggregate_fn
    ids, pows = _stream(5000, 100, int_powers=True)
    ref = aggregate_samples_np(ids, pows, 100)
    agg = StreamingAggregator(
        100, aggregate_fn=chunked_aggregate_fn(2048, interpret=True))
    for lo in range(0, len(ids), 1700):
        agg.update(ids[lo:lo + 1700], pows[lo:lo + 1700])
    for got, want in zip(agg.statistics(), ref):
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Columnar EstimateTable
# ---------------------------------------------------------------------------

def test_estimate_table_lazy_rows_match_columns():
    ids, pows = _stream(4000, 6, seed=21)
    names = [f"r{i}" for i in range(6)]
    est = estimate_regions(ids, pows, 3.0, names)
    tab = est.table
    assert len(tab) == len(est.regions)
    for i, r in enumerate(est.regions):
        assert r.region_id == int(tab.region_ids[i])
        assert r.e_hat == float(tab.e_hat[i])
        assert r.ci_valid == bool(tab.ci_valid[i])
    assert est.total_energy == pytest.approx(sum(r.e_hat for r in est.regions))
    assert est.dominant(2)[0].e_hat == max(r.e_hat for r in est.regions)


def test_estimates_from_statistics_roundtrip():
    ids, pows = _stream(4000, 6, seed=22)
    names = [f"r{i}" for i in range(6)]
    counts, psum, psumsq = aggregate_samples_np(ids, pows, 6)
    est_a = estimates_from_statistics(counts, psum, psumsq, 3.0, names)
    est_b = estimate_regions(ids, pows, 3.0, names)
    for a, b in zip(est_a.regions, est_b.regions):
        assert a == b


# ---------------------------------------------------------------------------
# Profiler / serve wiring
# ---------------------------------------------------------------------------

def test_profile_timeline_streaming_accuracy():
    costs = [RegionCost("attn", flops=4e11, hbm_bytes=1.5e10, invocations=8),
             RegionCost("ffn", flops=9e11, hbm_bytes=2.5e10, invocations=8)]
    tl = synthesize(costs, steps=150, seed=5)
    prof = EnergyProfiler(period=10e-3, seed=6)
    est = prof.profile_timeline_streaming(tl, sensor="rapl", chunk_size=512)
    gt = ground_truth(tl)
    for name, g in gt.items():
        r = est.by_name()[name]
        assert r.t_hat == pytest.approx(g["time"], rel=0.10)
        assert r.e_hat == pytest.approx(g["energy"], rel=0.12)


def test_profile_multiworker_streaming():
    costs = [RegionCost("mem", flops=1e10, hbm_bytes=5e10, invocations=4),
             RegionCost("alu", flops=6e11, hbm_bytes=2e9, invocations=4)]
    tls = [synthesize(costs, steps=120, seed=s) for s in (0, 1)]
    prof = EnergyProfiler(period=10e-3)
    est, combos = prof.profile_multiworker_streaming(
        tls, sensor="instant", chunk_size=256)
    assert len(combos) >= 2
    assert sum(r.t_hat for r in est.regions) == pytest.approx(
        min(t.t_exec for t in tls), rel=1e-6)


def test_phase_energy_accountant_streams_host_samples():
    from repro.core import regions as regions_mod
    from repro.serve.engine import PhaseEnergyAccountant

    # Thresholds deliberately loose (cf. test_host_session_smoke): on a
    # loaded host the control thread competes with the busy loop, which
    # stretches sleeps — attribution stays correct, busy fraction drops.
    acct = PhaseEnergyAccountant(period=1e-3, jitter=1e-4)
    with acct:
        for _ in range(120):
            with regions_mod.region("serve/busy"):
                t0 = time.monotonic()
                while time.monotonic() - t0 < 2e-3:
                    pass
            acct.drain()   # engine-style periodic fold; stream stays small
            with regions_mod.region("serve/idle"):
                time.sleep(0.5e-3)
    assert acct.agg.n_total >= 5
    est = acct.estimates()
    names = {r.name for r in est.regions}
    assert "serve/busy" in names
    assert est.by_name()["serve/busy"].p_hat > 0.1
