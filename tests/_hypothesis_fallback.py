"""Minimal deterministic stand-in for ``hypothesis`` when it's absent.

The tier-1 suite's property tests use only ``@given`` with keyword
``st.integers``/``st.floats`` strategies plus ``@settings(max_examples=...,
deadline=None)``. When hypothesis isn't installed in the container, this
shim runs each property test over ``max_examples`` fixed-seed random draws
instead of erroring at import — the suite degrades to deterministic
example-based testing rather than losing whole modules.

Install the real thing (``pip install -r requirements-dev.txt``) to get
shrinking, edge-case generation, and the example database.
"""

from __future__ import annotations

import functools
import inspect

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: np.random.Generator):
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        # hypothesis bounds are inclusive.
        return _Strategy(lambda rng: int(rng.integers(min_value,
                                                      max_value + 1)))

    @staticmethod
    def floats(min_value: float, max_value: float, **_: object) -> _Strategy:
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


st = _Strategies()


def settings(**kwargs):
    """Records max_examples on the decorated function (deadline ignored)."""
    def deco(fn):
        fn._fallback_settings = kwargs
        return fn
    return deco


def given(**strategies):
    """Runs the test over deterministic draws from the given strategies."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            s = (getattr(wrapper, "_fallback_settings", None)
                 or getattr(fn, "_fallback_settings", {}))
            rng = np.random.default_rng(0xA1EA)
            for _ in range(int(s.get("max_examples", 10))):
                drawn = {k: strat.example(rng)
                         for k, strat in strategies.items()}
                fn(*args, **{**kwargs, **drawn})
        # Hide the strategy-drawn parameters from pytest's fixture
        # resolution (inspect.signature would otherwise follow __wrapped__).
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items()
            if name not in strategies])
        return wrapper
    return deco
