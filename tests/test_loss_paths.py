"""Loss-path equivalences: fused chunked lm_head+CE vs plain CE (incl. the
VLM sliced-prefix path and non-divisor chunk fallback), and the perf-knob
variants (bf16_gather, decode_grouped) staying numerically faithful."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import model as M
from repro.optim.adamw import AdamWConfig
from repro.train.step import init_state, make_train_step


def test_fused_ce_matches_plain_dense():
    cfg = get_config("yi-6b").reduced().replace(compute_dtype="float32")
    key = jax.random.PRNGKey(0)
    p = M.init_params(key, cfg)
    batch = {"tokens": jax.random.randint(key, (2, 64), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (2, 64), 0, cfg.vocab_size)}
    l1, _ = M.loss_fn(p, cfg, batch, fuse_ce=False)
    l2, _ = M.loss_fn(p, cfg, batch, fuse_ce=True, ce_chunk=16)
    assert float(l1) == pytest.approx(float(l2), abs=1e-5)
    g1 = jax.grad(lambda p: M.loss_fn(p, cfg, batch, fuse_ce=False)[0])(p)
    g2 = jax.grad(lambda p: M.loss_fn(p, cfg, batch, fuse_ce=True,
                                      ce_chunk=16)[0])(p)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(a, b, atol=1e-6)


def test_fused_ce_vlm_and_nondivisor_chunk():
    cfg = get_config("internvl2-1b").reduced().replace(
        compute_dtype="float32")
    key = jax.random.PRNGKey(1)
    p = M.init_params(key, cfg)
    B, S, NP = 2, 64, 8
    batch = {"patch_embeds": 0.1 * jax.random.normal(
                 key, (B, NP, cfg.d_model), jnp.float32),
             "tokens": jax.random.randint(key, (B, S - NP), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S - NP), 0,
                                          cfg.vocab_size)}
    l1, _ = M.loss_fn(p, cfg, batch, fuse_ce=False)
    l2, _ = M.loss_fn(p, cfg, batch, fuse_ce=True, ce_chunk=16)
    l3, _ = M.loss_fn(p, cfg, batch, fuse_ce=True, ce_chunk=13)
    assert float(l1) == pytest.approx(float(l2), abs=1e-5)
    assert float(l1) == pytest.approx(float(l3), abs=1e-5)


def test_bf16_gather_close_to_fp32():
    """bf16 weight gathering changes numerics within bf16 rounding only."""
    cfg = get_config("qwen3-1.7b").reduced()
    key = jax.random.PRNGKey(2)
    opt_cfg = AdamWConfig(grad_clip=1e9)
    state = init_state(key, cfg, opt_cfg)
    batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (2, 32), 0, cfg.vocab_size)}
    _, m1 = jax.jit(make_train_step(cfg, opt_cfg))(state, batch)
    cfg2 = cfg.replace(bf16_gather=True)
    _, m2 = jax.jit(make_train_step(cfg2, opt_cfg))(state, batch)
    # compute is bf16 either way; only the cast point moves
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=2e-2)


def test_decode_grouped_matches_repeat():
    cfg = get_config("yi-6b").reduced().replace(compute_dtype="float32")
    p = M.init_params(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                              cfg.vocab_size)

    def roll(cfgx):
        cache = M.init_cache(cfgx, 2, 12, dtype=jnp.float32)
        outs = []
        for t in range(12):
            lg, cache = M.decode_step(p, cfgx, toks[:, t:t + 1], cache,
                                      jnp.int32(t))
            outs.append(lg)
        return jnp.concatenate(outs, 1)

    a = roll(cfg)
    b = roll(cfg.replace(decode_grouped=True))
    np.testing.assert_allclose(a, b, atol=1e-5)
