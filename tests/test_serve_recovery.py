"""Chaos suite for the serving seam: kill/restore, snapshot faults,
overload shedding, energy fences.

The acceptance scenario: an engine killed at an injected
``serve.step.crash``, restored from its last durable snapshot, yields
per-request token streams bit-exact to the uninterrupted run, with full
``ServeReport`` provenance (including shed and budget-aborted requests)
and no energy sample double-published past the spill-epoch fence.
"""

import numpy as np
import pytest

import jax

from repro.configs.registry import get_config
from repro.core import exchange as ex
from repro.core import faults
from repro.core.faults import (CorruptShardError, FaultPlan, InjectedCrash,
                               LeafFault, MissingArtifactError, SpillError,
                               TornWriteError)
from repro.models import model as M
from repro.serve.engine import Engine, PhaseEnergyAccountant, Request, ServeConfig
from repro.serve.recovery import restore_engine, snapshot
from repro.serve.scheduler import OverloadPolicy, ServeScheduler

pytestmark = pytest.mark.chaos

ARCH = "qwen3-1.7b"


@pytest.fixture(scope="module")
def arch_setup():
    cfg = get_config(ARCH).reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, n, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, size=int(rng.integers(3, 8)))
            .astype(np.int32) for _ in range(n)]


def _drive(eng, done):
    """Step until queue + slots drain; appends finished to ``done``."""
    for _ in range(500):
        done += eng.step()
        if (not any(r is not None for r in eng.slot_req)
                and not len(eng.scheduler.queue)):
            return
    raise AssertionError("engine did not drain")


# ---------------------------------------------------------------------------
# Acceptance: kill at serve.step.crash, restore, bit-exact streams +
# full provenance for every request including shed and budget-aborted.
# ---------------------------------------------------------------------------

def test_kill_restore_bit_exact_with_provenance(arch_setup, tmp_path):
    cfg, params = arch_setup
    scfg = ServeConfig(max_batch=2, max_len=64, step_energy=1.0)
    prompts = _prompts(cfg, 5)
    policy = OverloadPolicy(queue_capacity=3, backpressure_at=1,
                            shed_at=2, widen_at=3)

    def mk_reqs():
        reqs = [Request(i, prompts[i].copy(), max_new_tokens=5,
                        priority=i) for i in range(4)]
        # rid 4: budget covers prefill + 2 decode steps, then aborts.
        reqs.append(Request(4, prompts[4].copy(), max_new_tokens=16,
                            priority=9,
                            energy_budget=len(prompts[4]) + 2.0))
        return reqs

    def run(eng_factory, snap_dir=None, crash_plan=None):
        eng = eng_factory()
        shed_rids = []
        for r in mk_reqs():
            try:
                eng.submit(r)
            except Exception:        # queue-full rejections are typed+counted
                shed_rids.append(r.rid)
        done = []
        for _ in range(500):
            if snap_dir is not None and eng.step_count % 2 == 0:
                eng.snapshot(snap_dir)
            done += eng.step()
            if (not any(s is not None for s in eng.slot_req)
                    and not len(eng.scheduler.queue)):
                break
        return eng, done

    # Uninterrupted reference.
    ref_eng, ref_done = run(lambda: Engine(
        cfg, params, scfg, scheduler=ServeScheduler(policy)))
    ref_streams = {r.rid: list(r.out_tokens) for r in ref_done}

    # Interrupted: crash at step 5, restore from last snapshot, finish.
    snap = str(tmp_path / "snaps")
    plan = FaultPlan(seed=7, serve_crashes=(5,))
    with pytest.raises(InjectedCrash):
        run(lambda: Engine(cfg, params, scfg,
                           scheduler=ServeScheduler(policy), faults=plan),
            snap_dir=snap)

    eng2 = restore_engine(cfg, params, scfg, snap)
    assert eng2.step_count <= 5
    done2 = []
    _drive(eng2, done2)
    got = {r.rid: list(r.out_tokens) for r in done2}

    # Bit-exact: every request that reached a terminal state after the
    # restore matches the uninterrupted run token for token.
    for rid, toks in got.items():
        assert toks == ref_streams[rid], f"request {rid} diverged"

    # Full provenance: every submitted request has a record; the shed
    # and budget-aborted ones are counted, never silent.
    rep, ref_rep = eng2.report, ref_eng.report
    assert {r.rid for r in rep.requests} == set(range(5))
    by = rep.by_status()
    assert by == ref_rep.by_status()   # same terminal outcome per request
    assert rep.aborted_budget == 1 and rep.request(4).status == "aborted_budget"
    assert rep.shed + rep.rejected_full >= 1
    assert all(rep.request(r.rid).recovered for r in done2)
    assert rep.coverage()["counters"]["completed"] == rep.completed


# ---------------------------------------------------------------------------
# Snapshot durability faults: transient typed failures, corruption
# detection through the shared ckpt codec, missing-artifact typing.
# ---------------------------------------------------------------------------

def test_snapshot_fault_is_transient_and_typed(arch_setup, tmp_path):
    cfg, params = arch_setup
    scfg = ServeConfig(max_batch=1, max_len=32)
    eng = Engine(cfg, params, scfg,
                 faults=FaultPlan(seed=0, snapshot_failures=(0,)))
    eng.add_request(Request(0, _prompts(cfg, 1)[0], max_new_tokens=3))
    with pytest.raises(TornWriteError):
        eng.snapshot(str(tmp_path))
    assert not any((tmp_path / p).exists() for p in ("LATEST",))
    eng.step()                                  # step clock advances
    out = eng.snapshot(str(tmp_path))           # transient: next step fine
    assert out.endswith("snap_000000001")
    restored = restore_engine(cfg, params, scfg, str(tmp_path))
    assert restored.step_count == 1


def test_snapshot_corruption_surfaces_typed(arch_setup, tmp_path):
    cfg, params = arch_setup
    scfg = ServeConfig(max_batch=1, max_len=32)
    eng = Engine(cfg, params, scfg)
    eng.add_request(Request(0, _prompts(cfg, 1)[0], max_new_tokens=3))
    eng.step()
    with faults.install(FaultPlan(seed=1, leaf_faults=(
            LeafFault(match="snap_000000001/arr_00000"),))):
        eng.snapshot(str(tmp_path))             # storage rot on write
        with pytest.raises(SpillError):         # CRC catches it at read
            restore_engine(cfg, params, scfg, str(tmp_path))


def test_restore_without_snapshot_is_missing_artifact(arch_setup, tmp_path):
    cfg, params = arch_setup
    with pytest.raises(MissingArtifactError):
        restore_engine(cfg, params, ServeConfig(max_batch=1, max_len=32),
                       str(tmp_path))


def test_restore_rejects_geometry_mismatch(arch_setup, tmp_path):
    cfg, params = arch_setup
    scfg = ServeConfig(max_batch=2, max_len=32)
    eng = Engine(cfg, params, scfg)
    eng.snapshot(str(tmp_path))
    with pytest.raises(ValueError):
        restore_engine(cfg, params, ServeConfig(max_batch=4, max_len=32),
                       str(tmp_path))


# ---------------------------------------------------------------------------
# Overload: flood past capacity — backpressure, shed, sampling-period
# widening; every transition and victim recorded.
# ---------------------------------------------------------------------------

def test_overload_ladder_sheds_and_widens(arch_setup):
    cfg, params = arch_setup
    scfg = ServeConfig(max_batch=1, max_len=48)
    acct = PhaseEnergyAccountant(period=2e-3, track_requests=True)
    sched = ServeScheduler(OverloadPolicy(
        queue_capacity=8, backpressure_at=2, shed_at=4, widen_at=6))
    eng = Engine(cfg, params, scfg, accountant=acct, scheduler=sched)
    prompts = _prompts(cfg, 8, seed=11)
    with acct:
        submitted = rejected = 0
        for i in range(8):
            try:
                eng.submit(Request(i, prompts[i], max_new_tokens=3,
                                   priority=i % 3))
                submitted += 1
            except Exception:
                rejected += 1
        done = []
        # One step under full load: ladder must escalate to `degraded`
        # and widen the accountant's sampling period.
        done += eng.step()
        assert eng.scheduler.level == 3
        assert acct.sampling_period == pytest.approx(
            2e-3 * sched.policy.widen_factor)
        _drive(eng, done)
    # De-escalated on drain: period restored, transitions recorded.
    assert acct.sampling_period == pytest.approx(2e-3)
    rep = eng.report
    assert rep.shed >= 1                       # ladder shed queued work
    assert [t[2] for t in rep.transitions][-1] == "normal"
    assert rep.completed == len([r for r in done
                                 if r.status == "completed"])
    # Conservation of provenance: every submitted request terminal
    # (rejected_full is a sub-count of shed, not additive).
    assert rep.completed + rep.shed == 8
    assert rep.rejected_full <= rep.shed


# ---------------------------------------------------------------------------
# Self-speculative decoding chaos: kill mid-speculation (windows in
# flight), restore, and the merged streams must equal both the
# uninterrupted speculative run AND the non-speculative baseline — per
# cache family, since KV rewind and recurrent checkpoint/replay are
# different rollback mechanisms.
# ---------------------------------------------------------------------------

SPEC_ARCHS = ("qwen3-1.7b", "qwen3-moe-30b-a3b", "xlstm-125m",
              "zamba2-1.2b")


@pytest.mark.parametrize("arch", SPEC_ARCHS)
def test_kill_restore_mid_speculation_bit_exact(arch, tmp_path):
    cfg = get_config(arch).reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(max_batch=2, max_len=64, eos_token=-1,
                       step_energy=1.0, spec_len=4, spec_window=8,
                       spec_sinks=2)
    base_scfg = ServeConfig(max_batch=2, max_len=64, eos_token=-1,
                            step_energy=1.0)
    prompts = _prompts(cfg, 3, seed=9)

    def mk():
        return [Request(i, prompts[i].copy(), max_new_tokens=9)
                for i in range(3)]

    def run(scfg_, faults_=None, snap_dir=None):
        eng = Engine(cfg, params, scfg_, faults=faults_)
        reqs = mk()
        for r in reqs:
            eng.submit(r)
        for _ in range(500):
            if snap_dir is not None and eng.step_count % 2 == 0:
                eng.snapshot(snap_dir)
            eng.step()
            if (not any(s is not None for s in eng.slot_req)
                    and not len(eng.scheduler.queue)):
                break
        return {r.rid: list(r.out_tokens) for r in reqs}, eng

    baseline, _ = run(base_scfg)
    ref, ref_eng = run(scfg)
    assert ref == baseline                     # the correctness oracle
    assert ref_eng.report.drafted > 0          # speculation actually ran

    # Crash at step 3: snapshots exist at steps 0 and 2, so the restore
    # resumes from a window boundary with speculation still mid-stream
    # for every slot (windows are atomic on the step clock — see
    # serve/recovery.py).
    snap = str(tmp_path / "snaps")
    with pytest.raises(InjectedCrash):
        run(scfg, faults_=FaultPlan(seed=7, serve_crashes=(3,)),
            snap_dir=snap)
    eng2 = restore_engine(cfg, params, scfg, snap)
    assert eng2.step_count <= 3
    done2 = []
    _drive(eng2, done2)
    got = {rid: list(eng2._requests[rid].out_tokens) for rid in baseline}
    assert got == baseline, "restored speculative run diverged"
    # Counter sanity survives the restore: conservation still holds for
    # windows run after the snapshot.
    rep = eng2.report
    assert rep.accepted + rep.rejected == rep.drafted


def test_deescalation_restores_speculation_length(arch_setup):
    """Satellite fix: the degraded rung shrinks L (and widens sampling);
    de-escalation must restore BOTH through the single unwiden edge,
    transition-recorded — never leaving the engine permanently slow."""
    cfg, params = arch_setup
    scfg = ServeConfig(max_batch=1, max_len=48, spec_len=4,
                       spec_window=8, spec_sinks=2, degraded_spec_len=2)
    acct = PhaseEnergyAccountant(period=2e-3)
    sched = ServeScheduler(OverloadPolicy(
        queue_capacity=8, backpressure_at=2, shed_at=4, widen_at=6))
    eng = Engine(cfg, params, scfg, accountant=acct, scheduler=sched)
    prompts = _prompts(cfg, 8, seed=11)
    with acct:
        for i in range(8):
            try:
                eng.submit(Request(i, prompts[i], max_new_tokens=3,
                                   priority=i % 3))
            except Exception:
                pass
        done = []
        done += eng.step()
        assert eng.scheduler.level == 3 and eng.scheduler.widened
        # Degraded rung: speculation shrunk AND sampling widened, as one
        # ladder decision.
        assert eng._spec_len_now() == 2
        assert acct.sampling_period == pytest.approx(
            2e-3 * sched.policy.widen_factor)
        _drive(eng, done)
    # One reset path: both knobs restored together on de-escalation.
    assert not eng.scheduler.widened
    assert eng._spec_len_now() == 4
    assert acct.sampling_period == pytest.approx(2e-3)
    reasons = [t[3] for t in eng.report.transitions]
    assert any("speculation shrunk" in r for r in reasons)
    assert any("speculation length restored" in r for r in reasons)


def test_degraded_spec_len_none_disables_speculation(arch_setup):
    """degraded_spec_len=None means the overload response is to stop
    speculating entirely (drafting is extra work precisely when the
    host is drowning)."""
    cfg, params = arch_setup
    scfg = ServeConfig(max_batch=1, max_len=48, spec_len=4,
                       spec_window=8, spec_sinks=2)
    sched = ServeScheduler(OverloadPolicy(
        queue_capacity=8, backpressure_at=2, shed_at=4, widen_at=6))
    eng = Engine(cfg, params, scfg, scheduler=sched)
    prompts = _prompts(cfg, 8, seed=11)
    for i in range(8):
        try:
            eng.submit(Request(i, prompts[i], max_new_tokens=3,
                               priority=i % 3))
        except Exception:
            pass
    eng.step()
    assert eng.scheduler.widened
    assert eng._spec_len_now() == 0
    done = []
    _drive(eng, done)
    assert eng._spec_len_now() == 4


# ---------------------------------------------------------------------------
# Energy fence: a restored accountant resumes behind the spill-epoch
# fence — re-publishing pre-crash epochs is refused, never doubled.
# ---------------------------------------------------------------------------

def test_energy_spill_fence_never_double_counts(arch_setup, tmp_path):
    cfg, params = arch_setup
    scfg = ServeConfig(max_batch=1, max_len=32)
    spill = str(tmp_path / "shards")
    snaps = str(tmp_path / "snaps")
    prompts = _prompts(cfg, 2, seed=5)

    acct = PhaseEnergyAccountant(period=1e-3, spill_dir=spill,
                                 spill_every=1)
    eng = Engine(cfg, params, scfg, accountant=acct,
                 faults=FaultPlan(seed=2, serve_crashes=(3,)))
    with pytest.raises(InjectedCrash):
        with acct:
            eng.submit(Request(0, prompts[0], max_new_tokens=8))
            while True:
                eng.snapshot(snaps)
                eng.step()
    published = ex.restore_shard(spill, 0)[0].counts.sum()

    # Restart: same spill_dir/host_id resumes from LATEST shard; the
    # snapshot's fence records what was durable at kill time.
    acct2 = PhaseEnergyAccountant(period=1e-3, spill_dir=spill,
                                  spill_every=1)
    assert acct2.agg.counts.sum() == published     # resumed, not reset
    eng2 = restore_engine(cfg, params, scfg, snaps, accountant=acct2)
    assert eng2.restored_fence is not None
    assert acct2.epoch >= (eng2.restored_fence["last_spill_epoch"] or 0)
    with acct2:
        done = []
        _drive(eng2, done)
    final = ex.restore_shard(spill, 0)[0]
    # Monotone fence: the re-published shard extends the pre-crash one
    # (cumulative counts never shrink and are exactly the resumed
    # aggregator's — pre-crash samples ride once, not twice).
    assert final.counts.sum() == acct2.agg.counts.sum() >= published
    # And the spiller refuses to travel back behind the fence.
    with pytest.raises(ValueError):
        ex.ShardSpiller(spill, 0).spill(acct2.agg, epoch=1)
