"""Launch-path smoke: lower_cell compiles representative cells on a small
multi-pod mesh in a subprocess (device count must be set pre-jax-init;
this process keeps 1 device). One cell per family × step kind."""

import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import warnings; warnings.filterwarnings("ignore")
    import jax
    from repro.launch.dryrun import lower_cell
    from repro.launch.mesh import make_mesh_compat
    from repro.configs.registry import get_config

    mesh = make_mesh_compat((2, 2, 2), ("pod", "data", "model"))
    cells = [("yi-6b", "train_4k"), ("qwen3-moe-30b-a3b", "decode_32k"),
             ("zamba2-1.2b", "long_500k"), ("hubert-xlarge", "prefill_32k"),
             ("xlstm-125m", "decode_32k"), ("hubert-xlarge", "decode_32k")]
    for arch, shape in cells:
        cfg = get_config(arch).reduced()
        row, _ = lower_cell(arch, shape, multi_pod=True, mesh=mesh,
                            cfg_override=cfg)
        status = "SKIP" if "skipped" in row else "OK"
        print(f"CELL {arch} {shape} {status}")
    print("ALLDONE")
""")


@pytest.mark.slow
def test_dryrun_cells_compile_small_mesh():
    res = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, timeout=1500,
                         cwd="/root/repo")
    assert res.returncode == 0, res.stderr[-3000:]
    assert "ALLDONE" in res.stdout
    oks = [l for l in res.stdout.splitlines() if l.startswith("CELL")]
    assert len(oks) == 6
    # encoder-only decode must be a documented skip
    assert any("hubert-xlarge decode_32k SKIP" in l for l in oks)
