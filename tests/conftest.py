"""Shared test harness: an opt-in per-test wall-clock watchdog.

``--per-test-timeout=N`` arms a SIGALRM timer around every test so one
hung test (a deadlocked sampler thread, an exchange retry loop that
never converges) fails loudly with its nodeid instead of eating the
whole job's timeout budget. Implemented here rather than via
pytest-timeout so the gate works in any environment the suite runs in;
``@pytest.mark.timeout(seconds)`` overrides the limit per test. Default
is 0 (disabled) — local runs behave exactly as before; the tier-1 and
chaos CI lines pass an explicit budget.
"""

from __future__ import annotations

import signal

import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--per-test-timeout", type=float, default=0.0,
        help="fail any single test exceeding this many wall-clock "
             "seconds (0 disables; POSIX only)")


def _limit_for(item) -> float:
    mark = item.get_closest_marker("timeout")
    if mark is not None and mark.args:
        return float(mark.args[0])
    return float(item.config.getoption("--per-test-timeout"))


@pytest.hookimpl(wrapper=True)
def pytest_runtest_protocol(item, nextitem):
    limit = _limit_for(item)
    if limit <= 0 or not hasattr(signal, "SIGALRM"):
        return (yield)

    def _alarm(signum, frame):
        raise TimeoutError(
            f"{item.nodeid} exceeded per-test timeout of {limit:g}s")

    old = signal.signal(signal.SIGALRM, _alarm)
    signal.setitimer(signal.ITIMER_REAL, limit)
    try:
        return (yield)
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0.0)
        signal.signal(signal.SIGALRM, old)
