"""Per-architecture smoke tests (assignment deliverable f).

Each assigned arch instantiates a REDUCED same-family config and runs one
forward + one train (grad) step on CPU, asserting output shapes and no
NaNs. Full configs are exercised only via the dry-run.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.models import model as M

N_PATCH = 8


def make_batch(cfg, key, B=2, S=32):
    batch = {}
    if cfg.embed_inputs:
        batch["embeds"] = 0.1 * jax.random.normal(
            key, (B, S, cfg.d_model), jnp.float32)
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    elif cfg.family == "vlm":
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            key, (B, N_PATCH, cfg.d_model), jnp.float32)
        batch["tokens"] = jax.random.randint(
            key, (B, S - N_PATCH), 0, cfg.vocab_size)
        batch["labels"] = jax.random.randint(
            key, (B, S - N_PATCH), 0, cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    B, S = 2, 32
    batch = make_batch(cfg, key, B, S)

    logits, aux = jax.jit(lambda p, b: M.forward(p, cfg, b))(params, batch)
    exp_seq = S if cfg.family != "vlm" else S
    assert logits.shape == (B, exp_seq, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    (loss, metrics), grads = jax.jit(jax.value_and_grad(
        lambda p, b: M.loss_fn(p, cfg, b), has_aux=True))(params, batch)
    assert bool(jnp.isfinite(loss))
    # random-init loss near ln(V)
    import numpy as np
    assert float(loss) < np.log(cfg.vocab_size) + 2.0
    for g in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_config(a).causal])
def test_decode_matches_forward(arch):
    """KV/state-cache decode is consistent with the full forward."""
    cfg = get_config(arch).reduced().replace(compute_dtype="float32")
    if cfg.family == "moe":     # dropless capacity for exact equality
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts / cfg.top_k))
    key = jax.random.PRNGKey(1)
    params = M.init_params(key, cfg)
    B, S = 2, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch = {"tokens": tokens}
    else:
        batch = {"tokens": tokens}
    logits_full, _ = jax.jit(lambda p, b: M.forward(p, cfg, b))(params, batch)

    cache = M.init_cache(cfg, B, S, dtype=jnp.float32)
    step = jax.jit(lambda p, t, c, l: M.decode_step(p, cfg, t, c, l))
    outs = []
    for t in range(S):
        lg, cache = step(params, tokens[:, t:t + 1], cache, jnp.int32(t))
        outs.append(lg)
    logits_inc = jnp.concatenate(outs, axis=1)
    scale = float(jnp.max(jnp.abs(logits_full)))
    assert float(jnp.max(jnp.abs(logits_full - logits_inc))) < 1e-4 * scale


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_analytic_close(arch):
    """Analytic count (used for MODEL_FLOPS) tracks actual within 15%."""
    cfg = get_config(arch).reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    actual = sum(x.size for x in jax.tree.leaves(params))
    analytic = cfg.param_count()
    assert abs(actual - analytic) / actual < 0.40, (actual, analytic)


def test_chunked_attention_matches_full():
    cfg = get_config("yi-6b").reduced().replace(compute_dtype="float32")
    key = jax.random.PRNGKey(2)
    params = M.init_params(key, cfg)
    B, S = 2, 64
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    lg_full, _ = jax.jit(
        lambda p, b: M.forward(p, cfg, b, attn_impl="full"))(params, batch)
    lg_chunk, _ = jax.jit(
        lambda p, b: M.forward(p, cfg, b, attn_impl="chunked"))(params, batch)
    assert float(jnp.max(jnp.abs(lg_full - lg_chunk))) < 1e-4


def test_remat_matches_no_remat():
    cfg = get_config("qwen3-1.7b").reduced().replace(compute_dtype="float32")
    key = jax.random.PRNGKey(3)
    batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (2, 32), 0, cfg.vocab_size)}
    p = M.init_params(key, cfg.replace(remat="none"))
    g1 = jax.jit(jax.grad(
        lambda p, b: M.loss_fn(p, cfg.replace(remat="none"), b)[0]))(p, batch)
    g2 = jax.jit(jax.grad(
        lambda p, b: M.loss_fn(p, cfg.replace(remat="full"), b)[0]))(p, batch)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-5
