"""Multi-domain (power-rail) attribution: device/oracle equivalence,
D=1 golden-value regression vs pre-refactor main, and the domain axis
through estimator / report / streaming / serving layers."""

import json
import os

import numpy as np
import pytest

from repro.core import device_pipeline as dp
from repro.core.attribution import AttributionReport
from repro.core.power_model import POWER_DOMAINS, PowerModel
from repro.core.profiler import EnergyProfiler
from repro.core.sensors import (HostSensorBank, Ina231TraceSensor,
                                InstantTraceSensor, RaplTraceSensor,
                                SensorSpec)
from repro.core.streaming import StreamingAggregator, channels_for
from repro.core.timeline import RegionCost, ground_truth, synthesize

DATA = os.path.join(os.path.dirname(__file__), "data")

# Exactly the workload the golden file was generated from (pre-refactor
# main) — do not change without regenerating tests/data/golden_d1.json.
COSTS = [
    RegionCost("matmul", flops=2.4e12, hbm_bytes=1.6e9, invocations=3),
    RegionCost("attn", flops=0.8e12, hbm_bytes=2.4e9, ici_bytes=1e8,
               invocations=2),
    RegionCost("embed", flops=1e10, hbm_bytes=3.2e9, invocations=1),
    RegionCost("collective", flops=2e9, hbm_bytes=2e8, ici_bytes=6e8,
               invocations=2),
]

_SENSOR_SPECS = {
    "rapl": lambda domains: RaplTraceSensor.make_spec(5e-4,
                                                      domains=domains),
    "ina231": lambda domains: Ina231TraceSensor.make_spec(domains=domains),
    "instant": lambda domains: InstantTraceSensor.make_spec(
        domains=domains),
}


def _golden():
    with open(os.path.join(DATA, "golden_d1.json")) as f:
        return json.load(f)


def _unhex(hexes):
    return np.array([float.fromhex(h) for h in hexes])


# ---------------------------------------------------------------------------
# D=1 golden regression: bit-exact vs pre-refactor main.
# ---------------------------------------------------------------------------

def test_synthesize_scalar_powers_bit_exact_vs_golden():
    """synthesize() with default args consumes the RNG identically."""
    tl = synthesize(COSTS, steps=4, seed=3)
    g = _golden()["timeline"]
    assert [float(x).hex() for x in tl.powers[:16]] == g["powers_hex"]
    assert [float(x).hex() for x in tl.durations[:16]] == g["durations_hex"]


@pytest.mark.parametrize("sensor", ["rapl", "ina231", "instant"])
def test_region_pipeline_d1_bit_exact_vs_golden(sensor):
    """The fused device pipeline's D=1 statistics are bit-identical to
    the pre-rail pipeline (counts, Σpow, Σpow² — exact float bits)."""
    tl = synthesize(COSTS, steps=4, seed=3)
    spec = _SENSOR_SPECS[sensor](("total",))
    res = dp.run_region_pipeline(tl.to_device(), spec, period=5e-4,
                                 jitter=1e-4, seed=11, chunk_size=4096)
    g = _golden()[f"region/{sensor}"]
    assert res.counts.tolist() == g["counts"]
    assert [float(x).hex() for x in res.psum] == g["psum_hex"]
    assert [float(x).hex() for x in res.psumsq] == g["psumsq_hex"]
    assert res.n == g["n"]
    # The rail view of a scalar run is the single "total" rail itself.
    assert res.domains == ("total",)
    assert np.array_equal(res.rail_psum[:, 0], res.psum)


def test_reference_pipeline_d1_bit_exact_vs_golden():
    tl = synthesize(COSTS, steps=4, seed=3)
    spec = RaplTraceSensor.make_spec(5e-4)
    ref = dp.reference_region_pipeline(tl, spec, period=5e-4, jitter=1e-4,
                                       seed=11, chunk_size=4096)
    g = _golden()["ref_region/rapl"]
    assert ref.counts.tolist() == g["counts"]
    assert [float(x).hex() for x in ref.psum] == g["psum_hex"]
    assert [float(x).hex() for x in ref.psumsq] == g["psumsq_hex"]


def test_combo_pipeline_d1_bit_exact_vs_golden():
    """Multi-worker fused path: statistics AND interned combination ids
    match pre-refactor main bit-for-bit."""
    tls = [synthesize(COSTS, steps=3, seed=s) for s in (5, 6, 7, 8)]
    spec = RaplTraceSensor.make_spec(5e-4)
    agg, n = dp.run_combo_pipeline(dp.DeviceTimeline.from_timelines(tls),
                                   spec, period=5e-4, jitter=1e-4, seed=13,
                                   chunk_size=4096)
    g = _golden()["combo/rapl"]
    assert n == g["n"]
    assert agg.agg.counts.tolist() == g["counts"]
    assert [float(x).hex() for x in agg.agg.psum] == g["psum_hex"]
    assert [float(x).hex() for x in agg.agg.psumsq] == g["psumsq_hex"]
    assert agg.interner.combo_matrix().tolist() == g["combos"]


# ---------------------------------------------------------------------------
# Multi-domain equivalence: fused device pipeline vs numpy host oracle.
# ---------------------------------------------------------------------------

def _rel(a, b):
    return np.max(np.abs(a - b) / np.maximum(np.abs(b), 1e-30))


@pytest.mark.parametrize("sensor", ["rapl", "ina231", "instant"])
def test_region_pipeline_d3_matches_oracle(sensor):
    tl = synthesize(COSTS, steps=4, seed=3, domains=True)
    assert tl.domains == POWER_DOMAINS
    spec = _SENSOR_SPECS[sensor](tl.domain_names)
    res = dp.run_region_pipeline(tl.to_device(), spec, period=5e-4,
                                 jitter=1e-4, seed=11, chunk_size=4096)
    ref = dp.reference_region_pipeline(tl, spec, period=5e-4, jitter=1e-4,
                                       seed=11, chunk_size=4096)
    assert np.array_equal(res.counts, ref.counts)      # bit-exact counts
    assert res.domains == POWER_DOMAINS
    assert _rel(res.rail_psum, ref.rail_psum) < 1e-9
    assert _rel(res.rail_psumsq, ref.rail_psumsq) < 1e-9
    assert _rel(res.psum, ref.psum) < 1e-9
    # Per-domain sums reconstruct the scalar total.
    assert _rel(res.rail_psum.sum(axis=1), res.psum) < 1e-9


@pytest.mark.parametrize("sensor", ["rapl", "ina231", "instant"])
def test_combo_pipeline_d3_matches_oracle_w4(sensor):
    tls = [synthesize(COSTS, steps=2, seed=s, domains=True)
           for s in (5, 6, 7, 8)]
    spec = _SENSOR_SPECS[sensor](tls[0].domain_names)
    agg, n = dp.run_combo_pipeline(dp.DeviceTimeline.from_timelines(tls),
                                   spec, period=5e-4, jitter=1e-4, seed=13,
                                   chunk_size=4096)
    ragg, rn = dp.reference_combo_pipeline(tls, lambda tl: spec,
                                           period=5e-4, jitter=1e-4,
                                           seed=13, chunk_size=4096)
    assert n == rn
    assert np.array_equal(agg.agg.counts, ragg.agg.counts)
    assert (agg.interner.combo_matrix().tolist()
            == ragg.interner.combo_matrix().tolist())
    assert _rel(agg.agg.chan_psum, ragg.agg.chan_psum) < 1e-9
    assert _rel(agg.agg.chan_psumsq, ragg.agg.chan_psumsq) < 1e-9


def test_d3_rail_energy_matches_ground_truth():
    """Estimated per-domain energies converge on the exact per-rail
    integrals (the §6 compute-vs-memory split measured directly)."""
    tl = synthesize(COSTS, steps=6, seed=3, domains=True)
    prof = EnergyProfiler(period=2e-4, jitter=5e-5, seed=11)
    est = prof.profile_timeline_streaming(tl, sensor="instant",
                                          chunk_size=8192)
    truth = ground_truth(tl)
    gt_by_dom = {d: sum(v["energy_rails"][d] for v in truth.values())
                 for d in tl.domains}
    by_dom = est.energy_by_domain()
    assert set(by_dom) == set(POWER_DOMAINS)
    for d in POWER_DOMAINS:
        assert by_dom[d] == pytest.approx(gt_by_dom[d], rel=0.05)
    # The split is meaningful: matmul is package-dominated, embed is
    # HBM-heavy relative to its package share.
    rows = {r.name: r for r in est.regions}
    mm, em = rows["matmul"], rows["embed"]
    assert mm.energy_by_domain()["package"] > mm.energy_by_domain()["hbm"]
    assert (em.energy_by_domain()["hbm"] / em.e_hat
            > mm.energy_by_domain()["hbm"] / mm.e_hat)


def test_power_rails_sum_to_power():
    pm = PowerModel()
    rails = pm.power_rails(0.7, 0.4, 0.1, freq_scale=0.9,
                           mem_contention=1.5)
    total = pm.power(0.7, 0.4, 0.1, freq_scale=0.9, mem_contention=1.5)
    assert rails.shape == (3,)
    assert float(rails.sum()) == pytest.approx(float(total), rel=1e-12)


def test_synthesize_domains_rails_sum_to_scalar():
    tl = synthesize(COSTS, steps=2, seed=7, domains=True)
    np.testing.assert_allclose(tl.rail_powers.sum(axis=1), tl.powers,
                               rtol=1e-12)
    # Scalar stream identical with and without rails (same RNG draw).
    tl0 = synthesize(COSTS, steps=2, seed=7)
    assert np.array_equal(tl.powers, tl0.powers)
    assert np.array_equal(tl.durations, tl0.durations)


# ---------------------------------------------------------------------------
# Estimator / report / streaming / serving surfaces.
# ---------------------------------------------------------------------------

def test_domain_report_tables():
    tl = synthesize(COSTS, steps=3, seed=3, domains=True)
    prof = EnergyProfiler(period=5e-4, jitter=1e-4, seed=11)
    est = prof.profile_timeline_streaming(tl, sensor="instant",
                                          chunk_size=4096)
    rep = AttributionReport(est)
    txt = rep.domain_table()
    for d in POWER_DOMAINS:
        assert f"ê_{d}" in txt
    csv = rep.domain_csv()
    assert csv.splitlines()[0].startswith("region,n,e_hat,pow_package")
    # single-rail estimates refuse the domain breakdown loudly
    est1 = prof.profile_timeline_streaming(synthesize(COSTS, seed=3),
                                           sensor="instant",
                                           chunk_size=4096)
    with pytest.raises(ValueError):
        AttributionReport(est1).domain_table()


def test_streaming_aggregator_domain_axis():
    rng = np.random.default_rng(0)
    agg = StreamingAggregator(4, domains=POWER_DOMAINS)
    assert agg.num_channels == channels_for(POWER_DOMAINS) == 4
    ids = rng.integers(0, 4, 1000)
    rails = rng.uniform(10, 100, (1000, 3))
    agg.update(ids, rails)
    # total channel == sum of rails per sample, accumulated
    np.testing.assert_allclose(agg.psum, agg.rail_psum.sum(axis=1),
                               rtol=1e-12)
    # psumsq of the total is NOT the sum of rail psumsqs (squares don't
    # sum) — the dedicated channel must carry it.
    assert not np.allclose(agg.psumsq, agg.rail_psumsq.sum(axis=1))
    ref = np.zeros(4)
    np.add.at(ref, ids, rails.sum(axis=1) ** 2)
    np.testing.assert_allclose(agg.psumsq, ref, rtol=1e-12)
    # merge requires a matching domain axis
    with pytest.raises(ValueError, match="domain axis"):
        agg.merge(StreamingAggregator(4))
    # scalar powers into a multi-domain aggregator are rejected
    with pytest.raises(ValueError, match="scalar powers"):
        agg.update(ids[:5], np.ones(5))


def test_sensor_bank_spec_and_min_periods():
    spec = SensorSpec(kind="rapl", update_period=1e-3, min_period=1e-3,
                      domains=("package", "dram"),
                      min_periods=(1e-3, 5e-3))
    assert spec.num_domains == 2
    assert spec.effective_min_period() == 5e-3
    with pytest.raises(ValueError):
        SensorSpec(kind="rapl", domains=("a",), min_periods=(1.0, 2.0))
    # the device pipeline refuses periods under the slowest channel
    tl = synthesize(COSTS, steps=1, seed=0)
    with pytest.raises(ValueError, match="below sensor minimum"):
        dp.run_region_pipeline(
            tl.to_device(),
            SensorSpec(kind="instant", min_periods=(5e-2,)), period=1e-3)
    # and a channel-count / rail-count mismatch fails loudly
    tl3 = synthesize(COSTS, steps=1, seed=0, domains=True)
    with pytest.raises(ValueError, match="rail"):
        dp.run_region_pipeline(tl3.to_device(),
                               InstantTraceSensor.make_spec(),
                               period=1e-3)


def test_host_sensor_bank_and_sampler_channels():
    class Fake:
        min_period = 0.0

        def __init__(self, v):
            self.v = v

        def read(self, t=None):
            return self.v

    bank = HostSensorBank([("package", Fake(10.0)), ("dram", Fake(3.0))])
    assert bank.domains == ("package", "dram")
    np.testing.assert_array_equal(bank.read(), [10.0, 3.0])
    with pytest.raises(ValueError, match="duplicate"):
        HostSensorBank([("a", Fake(1.0)), ("a", Fake(2.0))])

    from repro.core.sampler import SampleBuffer
    buf = SampleBuffer(channels=2)
    buf.append(1, bank.read())
    buf.append(2, bank.read() * 2)
    rids, pows = buf.drain()
    assert pows.shape == (2, 2)
    np.testing.assert_array_equal(pows[1], [20.0, 6.0])
    # single-channel buffers keep the 1-D drain contract
    b1 = SampleBuffer()
    b1.append(0, 5.0)
    _, p1 = b1.drain()
    assert p1.shape == (1,)


def test_accountant_domain_energy(tmp_path):
    """Per-phase × per-domain serving accounting through a sensor bank."""
    from repro.core import regions as regions_mod
    from repro.serve.engine import PhaseEnergyAccountant

    class Fake:
        min_period = 0.0

        def __init__(self):
            self.domains = ("package", "dram")

        def read(self, t=None):
            return np.array([50.0, 20.0])

    acct = PhaseEnergyAccountant(period=1e-3, sensor=Fake())
    with acct:
        with regions_mod.region("phase_a"):
            t_stop = __import__("time").monotonic() + 0.05
            while __import__("time").monotonic() < t_stop:
                pass
    assert acct.drain() >= 0
    de = acct.domain_energy()
    row = next(iter(de.values()))
    assert set(row) == {"package", "dram"}
    est = acct.estimates()
    assert est.domains == ("package", "dram")
    by_dom = est.energy_by_domain()
    # 50 W vs 20 W split must be preserved ~exactly (constant readings)
    assert by_dom["package"] == pytest.approx(2.5 * by_dom["dram"],
                                              rel=1e-6)
