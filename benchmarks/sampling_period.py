"""Paper Figures 4/5: sampling period vs overhead vs energy-estimate error.

Sweeps the sampling period over a synthesized transformer-step timeline
with per-sample suspension overhead modeled two ways:
  * ``ptrace``: 50 µs stop-the-world per sample (the paper's mechanism);
  * ``marker``: ~0 (our TPU region-marker DMA — §4.8 adaptation).

Reproduces the paper's U-shape: short periods → overhead-dominated
systematic error; long periods → sampling-noise-dominated random error.
The paper's chosen 10 ms period should sit near the knee for ptrace.
"""

from __future__ import annotations

import numpy as np

from repro.configs.registry import get_config
from repro.configs.base import SHAPES
from repro.core import (EnergyProfiler, ground_truth, synthesize, validate)
from repro.roofline.cost_model import step_region_costs


def run(verbose: bool = True) -> list[str]:
    cfg = get_config("qwen3-1.7b")
    costs = step_region_costs(cfg, SHAPES["train_4k"])
    tl = synthesize(costs, steps=300, chips=256, seed=0)
    gt = ground_truth(tl)

    rows = []
    # RAPL counters update at 1 ms — the sensor floor (§4.5).
    periods = [1e-3, 2e-3, 5e-3, 10e-3, 20e-3, 50e-3, 100e-3]
    for mech, ovh in [("ptrace", 200e-6), ("marker", 1e-6)]:
        for period in periods:
            errs, werrs, overheads = [], [], []
            for seed in range(3):
                prof = EnergyProfiler(period=period, seed=seed)
                est = prof.profile_timeline(tl, sensor="rapl",
                                            overhead_per_sample=ovh)
                res = validate(est, gt)
                errs.append(res.mean_energy_err)
                # whole-program error exposes the systematic overhead bias
                werrs.append(res.whole_energy_err)
                overheads.append(ovh / period)
            name = f"sampling_period/{mech}/{period*1e3:g}ms"
            derived = (f"region_err={np.mean(errs)*100:.2f}%"
                       f" whole_err={np.mean(werrs)*100:.2f}%"
                       f" overhead={np.mean(overheads)*100:.2f}%")
            rows.append((name, period * 1e6, derived))
            if verbose:
                print(f"{name:40s} {derived}")
    return [f"{n},{us:.1f},{d}" for n, us, d in rows]


if __name__ == "__main__":
    run()
