"""End-to-end sampling→attribution pipeline benchmark (device tentpole).

Compares the two streaming backends of ``EnergyProfiler`` at equal sample
volume (``ALEA_BENCH_N`` samples, default 10⁶; acceptance runs use 10⁷):

* **host** — the chunked numpy path: ``iter_sample_chunks`` /
  ``iter_multiworker_chunks`` feeding ``StreamingAggregator`` /
  ``StreamingCombinationAggregator`` (per-chunk Python loop over W
  workers, host sensor emulation, host interning);
* **host_interp** — the same chunked host path with the PR-1 Pallas
  chunk kernel plugged into the aggregate seam
  (``chunked_aggregate_fn``), which on CPU runs in interpret mode — the
  configuration CI actually exercises today. Interpret mode is orders
  slower, so this arm is timed on a truncated stream (cf.
  ``benchmarks/aggregation.py``) and reported as samples/sec;
* **fused** — the device-resident pipeline
  (:mod:`repro.core.device_pipeline`): one jitted chunk step doing time
  generation, vmapped region lookup, sensor emulation and the attribution
  reduction into a donated device carry (XLA-compiled on CPU here; the
  Pallas kernel arm engages on real TPU).

Worker configurations W ∈ {1, 16, 64} model §4.4 barrier-synchronized
workers: one shared interval structure, per-worker sub-interval phase
shifts, so the combination space stays bounded (≈ R² transition pairs ×
W+1 crossing patterns) and the fused path reaches its steady state.
Fused timings exclude compilation (one warmup pass); host numpy needs no
warmup. Emits the usual CSV rows plus ``BENCH_pipeline.json``.
"""

from __future__ import annotations

import json
import os
import pathlib
import time

import numpy as np

from benchmarks.common import csv_row
from repro.core.sampler import iter_multiworker_chunks, iter_sample_chunks
from repro.core.sensors import RaplTraceSensor
from repro.core.streaming import (StreamingAggregator,
                                  StreamingCombinationAggregator)
from repro.core.timeline import Timeline

_JSON_PATH = pathlib.Path(__file__).with_name("BENCH_pipeline.json")
WORKER_CONFIGS = (1, 16, 64)
PERIOD = 1e-3          # RAPL-minimum sampling period → n ≈ t_end / PERIOD
JITTER = 200e-6
R = 16                 # regions per worker timeline
CHUNK = 16384          # cache sweet spot for BOTH arms at W=16 on CPU
SEED = 11


def _worker_timelines(w: int, n_samples: int, seed: int = 0
                      ) -> list[Timeline]:
    """W phase-shifted copies of one interval structure (§4.4 workers)."""
    t_end = n_samples * PERIOD
    m = int(min(20_000, max(200, n_samples // 50)))
    rng = np.random.default_rng(seed)
    durs = rng.uniform(0.5, 1.5, m) * (t_end / m)
    ids = rng.integers(0, R, m).astype(np.int32)
    pows = 50.0 + 150.0 * rng.random(m)
    names = tuple(f"bb_{i}" for i in range(R))
    tls = []
    for i in range(w):
        # Sub-interval phase shift via a leading pad interval: workers
        # stay within one interval of each other, so combinations are
        # transition patterns, not the full R^W cross product.
        off = (i / w) * 0.5 * (t_end / m) + 1e-9
        tls.append(Timeline(
            np.concatenate([[ids[0]], ids]),
            np.concatenate([[off], durs]),
            np.concatenate([[pows[0]], pows]), names))
    return tls


def _host_run(tls: list[Timeline], aggregate_fn=None,
              max_chunks: int | None = None):
    if len(tls) == 1:
        chunks = iter_sample_chunks(
            tls[0], RaplTraceSensor(tls[0]), period=PERIOD, jitter=JITTER,
            seed=SEED, chunk_size=CHUNK)
        agg = StreamingAggregator(R, aggregate_fn=aggregate_fn)
    else:
        chunks = iter_multiworker_chunks(
            tls, lambda tl: RaplTraceSensor(tl), period=PERIOD,
            jitter=JITTER, seed=SEED, chunk_size=CHUNK)
        agg = StreamingCombinationAggregator(aggregate_fn=aggregate_fn)
    for i, (rids, pows) in enumerate(chunks):
        if max_chunks is not None and i >= max_chunks:
            break
        agg.update(rids, pows)
    return agg.n_total


def _fused_run(tls: list[Timeline], stats: dict | None = None):
    from repro.core import device_pipeline as dp
    spec = RaplTraceSensor.make_spec()
    dtl = dp.DeviceTimeline.from_timelines(tls)
    if len(tls) == 1:
        res = dp.run_region_pipeline(dtl, spec, period=PERIOD,
                                     jitter=JITTER, seed=SEED,
                                     chunk_size=CHUNK)
        return res.n
    agg, n = dp.run_combo_pipeline(dtl, spec, period=PERIOD, jitter=JITTER,
                                   seed=SEED, chunk_size=CHUNK, stats=stats)
    return n


def run(verbose: bool = True) -> list[str]:
    n_target = int(os.environ.get("ALEA_BENCH_N", 1_000_000))
    rows: list[tuple[str, float, str]] = []
    record: dict = {"n_samples_target": n_target, "period": PERIOD,
                    "chunk": CHUNK, "regions": R, "sensor": "rapl",
                    "note": "fused timings exclude compilation "
                            "(one warmup pass)",
                    "workers": {}}

    from repro.kernels.sample_attr.ops import chunked_aggregate_fn
    interp_chunks = max(int(os.environ.get("ALEA_BENCH_INTERP_CHUNKS", 1)),
                        1)

    for w in WORKER_CONFIGS:
        tls = _worker_timelines(w, n_target, seed=SEED)

        t0 = time.perf_counter()
        n_host = _host_run(tls)
        host_dt = time.perf_counter() - t0

        # CI-mode host path: PR-1 Pallas chunk kernel in the aggregate
        # seam, interpret mode on CPU. Truncated — interpret is orders
        # slower; per-sample rate extrapolates (chunks are homogeneous).
        t0 = time.perf_counter()
        n_interp = _host_run(tls, chunked_aggregate_fn(interpret=True),
                             max_chunks=interp_chunks)
        interp_dt = time.perf_counter() - t0
        interp_rate = n_interp / interp_dt

        _fused_run(tls)                      # warmup: compile + table fill
        stats: dict = {}
        t0 = time.perf_counter()
        n_fused = _fused_run(tls, stats)
        fused_dt = time.perf_counter() - t0
        fused_rate = n_fused / fused_dt

        speedup = host_dt / fused_dt
        speedup_interp = fused_rate / interp_rate
        record["workers"][f"W{w}"] = {
            "n_samples": n_fused,
            "host": {"sec": host_dt, "samples_per_sec": n_host / host_dt},
            "host_interp": {"sec": interp_dt, "n_samples": n_interp,
                            "samples_per_sec": interp_rate},
            "fused": {"sec": fused_dt,
                      "samples_per_sec": fused_rate,
                      "speedup_vs_host": speedup,
                      "speedup_vs_host_interp": speedup_interp,
                      "miss_chunks": stats.get("miss_chunks"),
                      "chunks": stats.get("chunks")},
        }
        rows.append((f"pipeline/host/W{w}", host_dt * 1e6,
                     f"{n_host / host_dt / 1e6:.2f} Msamples/s"))
        rows.append((f"pipeline/host_interp/W{w}", interp_dt * 1e6,
                     f"{interp_rate / 1e6:.3f} Msamples/s n={n_interp}"))
        rows.append((f"pipeline/fused/W{w}", fused_dt * 1e6,
                     f"{fused_rate / 1e6:.2f} Msamples/s "
                     f"{speedup:.1f}x host {speedup_interp:.0f}x interp"))

    _JSON_PATH.write_text(json.dumps(record, indent=2))
    if verbose:
        for nm, us, d in rows:
            print(f"{nm:32s} {us:14.1f}us {d}")
        print(f"wrote {_JSON_PATH}")
    return [csv_row(nm, us, d) for nm, us, d in rows]


if __name__ == "__main__":
    run()
