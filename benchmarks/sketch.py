"""Bounded-state attribution benchmark: heavy-hitters tier memory sweep.

The exact combination table grows with every distinct (region, worker)
row it ever sees — unbounded on adversarial/streaming workloads (ALEA
targets always-on profiling; a profiler whose RSS tracks workload
cardinality is an outage, not an observer). The heavy-hitters tier
(``StreamingCombinationAggregator(k=...)``, see ``repro.core.sketch``)
caps the table at k identified rows plus one ``other`` row per region
while keeping per-region totals bit-exact.

This benchmark streams ``ALEA_BENCH_SKETCH_DISTINCT`` (default
``10000,100000,1000000``) distinct combination rows through the exact
aggregator and through bounded tables at k ∈ {256, 4096}, recording
resident rows and attribution-state bytes (key matrix + counts + Σpow
+ Σpow²) at each cardinality.

Emits CSV rows plus ``BENCH_sketch.json``. **Gate** (checked into the
JSON as ``gate_pass``): each bounded configuration's state bytes stay
flat within 1.5× across the full sweep — 100× distinct growth must not
buy more than 1.5× memory — while exact state grows with cardinality.
"""

from __future__ import annotations

import json
import os
import pathlib
import time

import numpy as np

from benchmarks.common import csv_row
from repro.core.streaming import StreamingCombinationAggregator

_JSON_PATH = pathlib.Path(__file__).with_name("BENCH_sketch.json")

REGIONS = 8
CHUNK = 1 << 15
HEAD = 128          # hot rows repeated every chunk (the heavy hitters)
GATE_RATIO = 1.5


def _distinct_sweep() -> list[int]:
    raw = os.environ.get("ALEA_BENCH_SKETCH_DISTINCT",
                         "10000,100000,1000000")
    return [int(v) for v in raw.split(",") if v]


def _state_bytes(agg: StreamingCombinationAggregator) -> int:
    """Resident attribution state: key matrix + (counts, Σpow, Σpow²)."""
    n = len(agg.interner)
    mat = agg.interner.combo_matrix()
    return int(mat.nbytes + agg.agg.counts[:n].nbytes
               + agg.agg.chan_psum[:n].nbytes
               + agg.agg.chan_psumsq[:n].nbytes)


def _stream(distinct: int, seed: int):
    """Chunked (rows, powers) stream covering ``distinct`` unique
    width-2 rows once each (the unbounded tail), plus a hot HEAD reseen
    every chunk so the tier has heavy hitters to keep."""
    rng = np.random.default_rng(seed)
    ids = rng.permutation(distinct)
    head = np.stack([np.arange(HEAD) % REGIONS,
                     np.arange(HEAD) // REGIONS], 1).astype(np.int64)
    for lo in range(0, distinct, CHUNK):
        tail_ids = ids[lo:lo + CHUNK]
        tail = np.stack([tail_ids % REGIONS,
                         HEAD // REGIONS + tail_ids // REGIONS],
                        1).astype(np.int64)
        mat = np.concatenate([head, tail])
        pows = rng.integers(50 * 64, 200 * 64, len(mat)) / 64.0
        yield mat, pows


def _run_mode(k: int | None, distinct: int) -> dict:
    agg = StreamingCombinationAggregator(k=k)
    t0 = time.perf_counter()
    n_samples = 0
    for mat, pows in _stream(distinct, seed=0):
        agg.update(mat, pows)
        n_samples += len(mat)
    dt = time.perf_counter() - t0
    return {"rows": len(agg.interner),
            "state_bytes": _state_bytes(agg),
            "tail_folds": agg.tail_folds,
            "evictions": agg.evictions,
            "sec": dt,
            "us_per_ksample": dt / n_samples * 1e9}


def run(verbose: bool = True) -> list[str]:
    sweep = _distinct_sweep()
    ks: list[int | None] = [None, 256, 4096]

    record: dict = {"distinct_sweep": sweep, "regions": REGIONS,
                    "head": HEAD, "gate_ratio": GATE_RATIO, "modes": {}}
    out_rows: list[tuple[str, float, str]] = []
    for k in ks:
        label = "exact" if k is None else f"k{k}"
        per = {}
        for d in sweep:
            per[str(d)] = _run_mode(k, d)
        record["modes"][label] = per
        worst = per[str(max(sweep))]
        out_rows.append((f"sketch/{label}/d{max(sweep)}",
                         worst["us_per_ksample"],
                         f"{worst['rows']} rows "
                         f"{worst['state_bytes'] / 1024:.1f} KiB "
                         f"{worst['tail_folds']} folds"))

    # Gate: bounded state flat within GATE_RATIO across the sweep.
    # Only saturated points count (distinct >= k): below saturation the
    # table legitimately tracks cardinality — the cap hasn't engaged.
    gate = True
    for k in ks:
        if k is None:
            continue
        per = record["modes"][f"k{k}"]
        sizes = [per[str(d)]["state_bytes"] for d in sweep if d >= k]
        if len(sizes) < 2:
            continue
        ratio = max(sizes) / min(sizes)
        record["modes"][f"k{k}"]["spread"] = ratio
        gate &= ratio <= GATE_RATIO
    record["gate_pass"] = bool(gate)
    exact_growth = (
        record["modes"]["exact"][str(max(sweep))]["state_bytes"]
        / record["modes"]["exact"][str(min(sweep))]["state_bytes"])
    record["exact_growth"] = exact_growth
    out_rows.append(("sketch/gate_flat_memory", 0.0,
                     f"{'PASS' if gate else 'FAIL'}: bounded spread <= "
                     f"{GATE_RATIO}x while exact grew {exact_growth:.0f}x"))

    _JSON_PATH.write_text(json.dumps(record, indent=2))
    if verbose:
        for nm, us, d_ in out_rows:
            print(f"{nm:40s} {us:12.1f}us {d_}")
        print(f"wrote {_JSON_PATH}")
    return [csv_row(nm, us, d_) for nm, us, d_ in out_rows]


if __name__ == "__main__":
    run()
