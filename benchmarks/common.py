"""Shared helpers for the benchmark suite."""

from __future__ import annotations

import time


def timed(fn, *args, reps: int = 3, **kw):
    """(result, us_per_call) with one warmup."""
    fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / reps
    return out, dt * 1e6


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
