"""Self-speculative serving Pareto sweep: accepted-tokens-per-joule.

The speculative hot path trades (L-1) cheap windowed draft steps plus
one L-wide verify sweep for up to L emitted tokens per window, against
the baseline's one full step per token. Under the deterministic
``step_energy`` proxy the trade is exact arithmetic, so this benchmark
is a *gate*, not a timing estimate:

* per window a slot is charged ``draft_energy * (L-1) + step_energy``
  (draft_energy defaults to ``step_energy * (window + sinks) / max_len``
  — the one-cache-sweep verify cost model), and emits between 1 and L
  tokens depending on acceptance;
* the baseline (L=0) charges ``step_energy`` per emitted token.

The headline cell — L=4, B=32 — must clear **1.2x** the baseline's
tokens-per-proxy-joule or the run fails loudly (RuntimeError after the
JSON is written, so the failing numbers are inspectable). Wall-clock
tokens/sec rides along unguarded: CPU-backend timings are indicative
only, the energy-proxy ratio is the contract.

Sweeps L in {0, 2, 4, 8} x B in {8, 32}; emits CSV rows plus
``BENCH_serve_spec.json``.
"""

from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from benchmarks.common import csv_row

_JSON_PATH = pathlib.Path(__file__).with_name("BENCH_serve_spec.json")

SPEC_LENS = (0, 2, 4, 8)
BATCHES = (8, 32)
MAX_NEW = 16
PROMPT_LEN = 5
MAX_LEN = 128
WINDOW = 32
SINKS = 4
STEP_ENERGY = 1.0

GATE_CELL = (4, 32)          # (L, B) headline cell
GATE_BASELINE = (0, 32)
GATE_MIN_RATIO = 1.2


def _requests(cfg, n, seed=0):
    from repro.serve.engine import Request
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(1, cfg.vocab_size, PROMPT_LEN)
                    .astype(np.int32), max_new_tokens=MAX_NEW)
            for i in range(n)]


def _bench_cell(cfg, params, L, B):
    from repro.serve.engine import Engine, ServeConfig

    scfg = ServeConfig(max_batch=B, max_len=MAX_LEN, eos_token=-1,
                       step_energy=STEP_ENERGY, spec_len=L,
                       spec_window=WINDOW, spec_sinks=SINKS)
    eng = Engine(cfg, params, scfg)
    reqs = _requests(cfg, B)
    t0 = time.perf_counter()
    done = eng.run_until_drained(reqs)
    wall_s = time.perf_counter() - t0
    assert len(done) == B and all(r.done for r in done)

    tokens = sum(len(r.out_tokens) for r in done)
    total_j = sum(r.energy_j for r in done)
    # Prefill is charged identically in every cell; subtract it so the
    # ratio compares the decode hot path only.
    decode_units = (total_j - B * PROMPT_LEN * STEP_ENERGY) / STEP_ENERGY
    rep = eng.report
    out = {
        "tokens": tokens,
        "steps": eng.step_count,
        "decode_energy_units": decode_units,
        "tokens_per_unit": tokens / decode_units,
        "units_per_token": decode_units / tokens,
        "wall_s": wall_s,
        "tokens_per_s": tokens / wall_s,
    }
    if L:
        out["drafted"] = rep.drafted
        out["accepted"] = rep.accepted
        out["acceptance"] = rep.accepted / max(rep.drafted, 1)
        out["rollbacks"] = rep.rollbacks
    return out


def run(verbose: bool = True) -> list[str]:
    import jax
    from repro.configs.registry import get_config
    from repro.models import model as M

    cfg = get_config("qwen3-1.7b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    rows: list[str] = []
    results: dict[str, dict] = {}
    for B in BATCHES:
        for L in SPEC_LENS:
            r = _bench_cell(cfg, params, L, B)
            results[f"L{L}_B{B}"] = r
            acc = (f" acc={r['acceptance']:.3f}" if L else "")
            rows.append(csv_row(
                f"serve_spec_L{L}_B{B}", r["wall_s"] * 1e6,
                f"tok_per_unit={r['tokens_per_unit']:.3f} "
                f"steps={r['steps']}{acc}"))

    gl, gb = GATE_CELL, GATE_BASELINE
    ratio = (results[f"L{gl[0]}_B{gl[1]}"]["tokens_per_unit"]
             / results[f"L{gb[0]}_B{gb[1]}"]["tokens_per_unit"])
    gate = {"cell": f"L{gl[0]}_B{gl[1]}", "baseline": f"L{gb[0]}_B{gb[1]}",
            "min_ratio": GATE_MIN_RATIO, "ratio": ratio,
            "met": ratio >= GATE_MIN_RATIO}
    rows.append(csv_row(
        "serve_spec_gate", 0.0,
        f"ratio={ratio:.3f}_min={GATE_MIN_RATIO}_met={gate['met']}"))
    _JSON_PATH.write_text(json.dumps(
        {"spec_lens": list(SPEC_LENS), "batches": list(BATCHES),
         "max_new_tokens": MAX_NEW, "prompt_len": PROMPT_LEN,
         "max_len": MAX_LEN, "window": WINDOW, "sinks": SINKS,
         "step_energy": STEP_ENERGY, "results": results, "gate": gate},
        indent=2))
    if verbose:
        print("\n".join(rows))
    if not gate["met"]:
        raise RuntimeError(
            f"speculative energy gate FAILED: tokens-per-proxy-joule "
            f"ratio {ratio:.3f} < {GATE_MIN_RATIO} "
            f"({gate['cell']} vs {gate['baseline']}; see {_JSON_PATH})")
    return rows


if __name__ == "__main__":
    run()
