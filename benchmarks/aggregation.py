"""Aggregation + estimator pipeline benchmarks (streaming tentpole).

Tracks the ALEA hot path end to end:

* samples/sec of the one-shot numpy aggregation vs the constant-memory
  ``StreamingAggregator`` at several chunk sizes vs the Pallas chunked
  kernel (interpret mode on CPU — correctness-path timing only), at
  R ∈ {64, 2048, 8192} (8192 exercises the region-tiled kernel grid);
* the vectorized ``_build_estimates`` vs the seed's per-region Python
  loop at 10⁴ regions (multi-worker combination-table scale).

Emits the usual CSV rows plus ``BENCH_aggregation.json`` next to this
file so the perf trajectory is tracked across PRs. ``ALEA_BENCH_N``
scales the sample count (default 10⁶; acceptance runs use 10⁷).
"""

from __future__ import annotations

import json
import math
import os
import pathlib
import time

import numpy as np

from benchmarks.common import csv_row, timed
from repro.core.estimator import (RegionEstimate, aggregate_samples_np,
                                  estimates_from_statistics, z_quantile)
from repro.core.streaming import StreamingAggregator

_JSON_PATH = pathlib.Path(__file__).with_name("BENCH_aggregation.json")


def _build_estimates_loop(counts, psum, psumsq, names, t_exec, alpha):
    """The seed's per-region Python loop, kept verbatim as the baseline
    the vectorized ``_build_estimates`` is measured against."""
    n = int(counts.sum())
    z = z_quantile(alpha)
    out = []
    for rid in range(len(counts)):
        n_bb = int(counts[rid])
        if n_bb == 0:
            continue
        p_hat = n_bb / n
        se_p = math.sqrt(max(p_hat * (1.0 - p_hat), 0.0) / n)
        p_lo = max(p_hat - z * se_p, 0.0)
        p_hi = min(p_hat + z * se_p, 1.0)
        t_hat = p_hat * t_exec
        pow_hat = psum[rid] / n_bb if n_bb > 0 else 0.0
        if n_bb > 1:
            var = (psumsq[rid] - n_bb * pow_hat * pow_hat) / (n_bb - 1)
            se_pow = math.sqrt(max(var, 0.0)) / math.sqrt(n_bb)
        else:
            se_pow = 0.0
        pow_lo, pow_hi = pow_hat - z * se_pow, pow_hat + z * se_pow
        out.append(RegionEstimate(
            region_id=rid, name=names[rid], n_samples=n_bb, p_hat=p_hat,
            t_hat=t_hat, t_lo=p_lo * t_exec, t_hi=p_hi * t_exec,
            pow_hat=float(pow_hat), pow_lo=float(pow_lo),
            pow_hi=float(pow_hi), e_hat=float(pow_hat * t_hat),
            e_lo=float(p_lo * t_exec * pow_lo),
            e_hi=float(p_hi * t_exec * pow_hi),
            ci_valid=(n * p_hat > 5.0) and (n * (1.0 - p_hat) > 5.0)))
    return tuple(out)


def _time_once(fn):
    fn()                       # warmup
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run(verbose: bool = True) -> list[str]:
    n = int(os.environ.get("ALEA_BENCH_N", 1_000_000))
    rng = np.random.default_rng(0)
    rows: list[tuple[str, float, str]] = []
    record: dict = {"n_samples": n, "aggregation": {}, "estimator": {}}

    for R in (64, 2048, 8192):
        ids = rng.integers(0, R, n).astype(np.int32)
        pows = (50.0 + 150.0 * rng.random(n))
        entry: dict = {}

        dt = _time_once(lambda: aggregate_samples_np(ids, pows, R))
        oneshot_dt = dt
        entry["oneshot_numpy"] = {"sec": dt, "samples_per_sec": n / dt}
        rows.append((f"aggregation/oneshot/R{R}", dt * 1e6,
                     f"{n / dt / 1e6:.1f} Msamples/s"))

        for chunk in (4096, 65536, 262144):
            def go(chunk=chunk):
                agg = StreamingAggregator(R)
                for lo in range(0, n, chunk):
                    agg.update(ids[lo:lo + chunk], pows[lo:lo + chunk])
                return agg
            dt = _time_once(go)
            entry[f"streaming_chunk{chunk}"] = {
                "sec": dt, "samples_per_sec": n / dt,
                "vs_oneshot": dt / oneshot_dt}
            rows.append((f"aggregation/stream_c{chunk}/R{R}", dt * 1e6,
                         f"{n / dt / 1e6:.1f} Msamples/s "
                         f"{dt / oneshot_dt:.2f}x oneshot"))

        # Pallas chunked kernel, interpret mode: correctness-path timing on
        # a reduced stream (interpret is orders slower than compiled TPU).
        from repro.kernels.sample_attr.ops import chunked_aggregate_fn
        n_p = min(n, 65536)
        agg_fn = chunked_aggregate_fn(16384, interpret=True)
        def go_pallas():
            agg = StreamingAggregator(R, aggregate_fn=agg_fn)
            agg.update(ids[:n_p], pows[:n_p])
            return agg
        dt = _time_once(go_pallas)
        entry["pallas_interpret"] = {"sec": dt, "n": n_p,
                                     "samples_per_sec": n_p / dt}
        rows.append((f"aggregation/pallas_interp/R{R}", dt * 1e6,
                     f"{n_p / dt / 1e6:.2f} Msamples/s n={n_p}"))
        record["aggregation"][f"R{R}"] = entry

    # Estimator build: vectorized table vs seed per-region loop at 10^4
    # regions (the multi-worker combination-count regime).
    R_est = 10_000
    counts = rng.integers(1, 50, R_est).astype(np.int64)
    psum = counts * (60.0 + 10.0 * rng.random(R_est))
    psumsq = psum * psum / counts * 1.01
    names = [f"comb_{i}" for i in range(R_est)]
    dt_loop = _time_once(lambda: _build_estimates_loop(
        counts, psum, psumsq, names, 10.0, 0.05))
    dt_vec = _time_once(lambda: estimates_from_statistics(
        counts, psum, psumsq, 10.0, names))
    speedup = dt_loop / dt_vec
    record["estimator"] = {"num_regions": R_est, "loop_sec": dt_loop,
                           "vectorized_sec": dt_vec, "speedup": speedup}
    rows.append((f"estimator/build_loop/R{R_est}", dt_loop * 1e6, "seed loop"))
    rows.append((f"estimator/build_vectorized/R{R_est}", dt_vec * 1e6,
                 f"{speedup:.1f}x over loop"))

    _JSON_PATH.write_text(json.dumps(record, indent=2))
    if verbose:
        for nm, us, d in rows:
            print(f"{nm:44s} {us:12.1f}us {d}")
        print(f"wrote {_JSON_PATH}")
    return [csv_row(nm, us, d) for nm, us, d in rows]


if __name__ == "__main__":
    run()
