"""Paper §6 (Table 1, Figures 8/9): power vs memory-access intensity.

TPU-native recreation of the BBA microbenchmark family as fixed-duration
regions with explicit activity levels (the paper builds each variant from
BBA's instruction groups; we build each from its resource utilizations):

  Nop       — busy-wait: no MXU, no HBM            u=(0.02, 0.01)
  NoMem     — MXU-only (VREG/VMEM-resident FLOPs)  u=(0.90, 0.02)
  Mem(VMEM) — working set resident in VMEM         u=(0.05, 0.20)
  Mem(HBM)  — streaming from HBM                   u=(0.05, 0.90)
  BBA       — fused compute+memory, SAME duration as NoMem because the
              pipeline hides the loads (paper's pipelining effect)

Findings reproduced:
  (1) memory activity alone raises package power substantially with zero
      compute — the paper's core §6 effect;
  (1') TPU delta (DESIGN.md §2): unlike the paper's CPUs, MXU activity
      also raises power strongly — both terms are first-class here;
  (2) pipelining: E(BBA) ≪ E(NoMem)+E(Mem) → EPI-additive models
      overestimate ~1.3–1.5×;
  (3) §6.2 contention: package power of memory-bound regions grows
      superlinearly with co-running workers.

Note — per-domain attribution now measures this split *directly*: the
profiler threads a power-rail axis (package/HBM/ICI) end to end, so a
multi-domain run reports each block's energy per rail instead of
inferring the compute-vs-memory decomposition from activity
coefficients as this table does. ``benchmarks/domains.py``
(→ ``BENCH_domains.json``) reproduces the §6 compute-vs-memory split
from rail attribution on a synthesized workload and benchmarks the cost
of the domain axis (D=3 vs D=1 fused-pipeline throughput).
"""

from __future__ import annotations

from repro.core.power_model import PowerModel

DUR = 10e-3     # fixed region duration [s]

VARIANTS = {
    "Nop":       (0.02, 0.01, DUR),
    "NoMem":     (0.90, 0.02, DUR),
    "Mem(VMEM)": (0.05, 0.20, DUR),
    "Mem(HBM)":  (0.05, 0.90, DUR),
    "BBA":       (0.90, 0.90, DUR),      # pipelined union, same duration
}


def run(verbose: bool = True) -> list[str]:
    pm = PowerModel()
    rows = []
    results = {}
    for name, (uf, um, dur) in VARIANTS.items():
        pw = float(pm.power(uf, um, 0.0))
        e = pw * dur
        results[name] = (dur, pw, e)
        derived = f"power={pw:.1f}W time={dur*1e3:.2f}ms energy={e:.2f}J"
        rows.append((f"memory_power/{name}", dur * 1e6, derived))
        if verbose:
            print(f"{'memory_power/' + name:28s} {derived}")

    p_nop = results["Nop"][1]
    f1 = (f"memory-only adds {results['Mem(HBM)'][1]-p_nop:.1f}W over idle; "
          f"compute-only adds {results['NoMem'][1]-p_nop:.1f}W "
          f"(TPU delta: MXU is also a first-class power term)")
    rows.append(("memory_power/activity_effect", 0.0, f1))

    e_bba = results["BBA"][2]
    e_sum = results["NoMem"][2] + results["Mem(HBM)"][2]
    f2 = f"EPI-additive overestimate: {e_sum/e_bba:.2f}x (paper: 1.29-1.5x)"
    rows.append(("memory_power/pipelining_effect", 0.0, f2))

    workers_rows = []
    for w in (1, 2, 4, 8):
        pw = float(pm.power(0.05, 0.9, 0.0, mem_contention=w - 1.0))
        workers_rows.append(f"{w}w={pw:.1f}W")
    f3 = "mem-region package power: " + " ".join(workers_rows)
    rows.append(("memory_power/contention", 0.0, f3))

    f4 = ("per-domain attribution measures this split directly now — "
          "see domains benchmark (BENCH_domains.json)")
    rows.append(("memory_power/direct_measurement_note", 0.0, f4))

    if verbose:
        print(f1)
        print(f2)
        print(f3)
        print(f4)
    return [f"{n},{us:.1f},{d}" for n, us, d in rows]


if __name__ == "__main__":
    run()
