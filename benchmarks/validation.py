"""Paper §5 / Figure 6: ALEA accuracy validation across the benchmark suite.

The paper validates on 14 SPEC/PARSEC/Rodinia benchmarks; our suite is the
10 assigned architectures (timelines synthesized from the analytic
per-region cost model at the production chip count). For each arch:

  * sequential run (1 worker): per-region time/energy error vs exact
    ground truth + whole-program error + 95%-CI coverage;
  * parallel run (4 workers, §4.4): combination-level attribution error.

Paper targets: coarse-grain mean energy error 1.4–1.9%, fine-grain
1.6–3.5%, ~99% of measurements within 95% CIs.
"""

from __future__ import annotations

import numpy as np

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS, get_config
from repro.core import EnergyProfiler, ground_truth, synthesize, validate
from repro.core.estimator import estimate_regions
from repro.roofline.cost_model import step_region_costs


def run(verbose: bool = True, steps: int | None = None) -> list[str]:
    period = 10e-3
    rows = []
    seq_t, seq_e, par_e, cov, frac = [], [], [], [], []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        # Deploy-realistic chip count: small models train on few chips
        # (which also keeps region spans resolvable, as in the paper's
        # single-node benchmarks).
        chips = int(np.clip(cfg.param_count() / 50e6, 8, 256))
        costs = step_region_costs(cfg, SHAPES["train_4k"], chips=chips)
        # Run long enough for ~20k samples (the paper repeats runs until
        # CIs tighten to 5%); one synthesized step probes the step time.
        probe = synthesize(costs, steps=1, chips=chips, seed=0)
        n_steps = steps or int(np.clip(200.0 / probe.t_exec, 50, 20000))
        tl = synthesize(costs, steps=n_steps, chips=chips,
                        seed=hash(arch) % 999)
        gt = ground_truth(tl)
        # §5 protocol: validate only regions direct measurement resolves —
        # contiguous span (invocation run per step) ≥ sampling period.
        spans = {name: v["time"] / n_steps for name, v in gt.items()}
        prof = EnergyProfiler(period=period, seed=1)
        est = prof.profile_timeline(tl, sensor="rapl")
        res = validate(est, gt, spans=spans, min_span=period)
        seq_t.append(res.mean_time_err)
        seq_e.append(res.mean_energy_err)
        cov.append(res.ci_energy_coverage)
        frac.append(res.measured_time_fraction)

        # Parallel (§4.4): 4 workers with per-worker latency jitter.
        tls = [synthesize(costs, steps=max(n_steps // 4, 10), chips=chips,
                          seed=s) for s in range(4)]
        est_c, combos = prof.profile_multiworker(tls, sensor="instant")
        # whole-run energy conservation through combinations:
        gt_total = sum(sum(v["energy"] for v in ground_truth(t).values())
                       for t in tls) / 4
        est_total = est_c.total_energy / 4
        par_err = abs(est_total - gt_total * (est_c.t_exec * 4 / sum(
            t.t_exec for t in tls))) / max(gt_total, 1e-9)
        par_e.append(min(par_err, 1.0))

        name = f"validation/{arch}"
        derived = (f"time_err={res.mean_time_err*100:.2f}%"
                   f" energy_err={res.mean_energy_err*100:.2f}%"
                   f" whole={res.whole_energy_err*100:.2f}%"
                   f" ci_cov={res.ci_energy_coverage*100:.0f}%"
                   f" measured={res.measured_time_fraction*100:.0f}%"
                   f" par_energy_err={par_e[-1]*100:.2f}%")
        rows.append((name, tl.t_exec * 1e6 / n_steps, derived))
        if verbose:
            print(f"{name:36s} {derived}")

    summary = (f"MEAN: time {np.mean(seq_t)*100:.2f}% "
               f"energy {np.mean(seq_e)*100:.2f}% "
               f"(paper: 1.3-3.5%) ci_cov {np.mean(cov)*100:.0f}% "
               f"measured {np.mean(frac)*100:.0f}% (paper: 81%)")
    rows.append(("validation/MEAN", 0.0, summary))
    if verbose:
        print(summary)
    return [f"{n},{us:.1f},{d}" for n, us, d in rows]


if __name__ == "__main__":
    run()
