"""Shard-exchange benchmarks (cross-host reduction tentpole).

Merge throughput of the exchange paths as the fleet grows, at the
multi-worker combination-table scale the estimator targets (10⁴ distinct
combination rows per shard):

* in-memory tree-reduce (``merge_table`` lazy interner dedup) vs shard
  count S ∈ {2, 4, 8, 16} — the CPU cost every gather pays;
* checkpointed round trip (``spill_shard`` × S + ``gather_shards``) —
  adds manifest+CRC+atomic-rename I/O;
* the packed wire format itself (``pack_shard``/``unpack_shard``).

Emits the usual CSV rows plus ``BENCH_exchange.json`` next to this file
so the trajectory is tracked across PRs. ``ALEA_BENCH_ROWS`` scales the
per-shard combination count (default 10⁴).
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import csv_row
from repro.core import exchange as ex
from repro.core.streaming import StreamingCombinationAggregator

_JSON_PATH = pathlib.Path(__file__).with_name("BENCH_exchange.json")


def _make_shards(n_shards: int, rows: int, seed: int = 0):
    """Shards with ~``rows`` distinct combination rows each, overlapping
    id spaces (the realistic dedup-heavy regime)."""
    rng = np.random.default_rng(seed)
    width = 2
    R = max(int(np.sqrt(2 * rows)), 2)   # ~R²/2 distinct pairs observable
    shards = []
    for _ in range(n_shards):
        mat = rng.integers(0, R, (2 * rows, width)).astype(np.int64)
        pows = rng.integers(50 * 64, 200 * 64, 2 * rows) / 64.0
        shards.append(StreamingCombinationAggregator().update(mat, pows))
    return shards


def _time_once(fn):
    fn()                       # warmup
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def _tree_reduce(shards):
    # Fresh aggregators so the timed merge never mutates the inputs;
    # the reduction itself is the production gather path.
    return ex.tree_reduce(
        [StreamingCombinationAggregator().merge(s) for s in shards])


def run(verbose: bool = True) -> list[str]:
    rows_per_shard = int(os.environ.get("ALEA_BENCH_ROWS", 10_000))
    rows: list[tuple[str, float, str]] = []
    record: dict = {"rows_per_shard": rows_per_shard, "merge": {},
                    "checkpointed": {}, "wire": {}}

    for S in (2, 4, 8, 16):
        shards = _make_shards(S, rows_per_shard, seed=S)
        total_rows = sum(len(s.interner) for s in shards)

        merged, dt = _time_once(lambda: _tree_reduce(shards))
        union = len(merged.interner)
        record["merge"][f"S{S}"] = {
            "sec": dt, "union_rows": union, "input_rows": total_rows,
            "rows_per_sec": total_rows / dt}
        rows.append((f"exchange/tree_merge/S{S}", dt * 1e6,
                     f"{total_rows / dt / 1e6:.2f} Mrows/s union={union}"))

        d = tempfile.mkdtemp(prefix="bench_exchange_")
        try:
            def spill_gather():
                for h, s in enumerate(shards):
                    ex.spill_shard(d, h, epoch=1, agg=s)
                return ex.gather_shards(d)
            _, dt_ck = _time_once(spill_gather)
        finally:
            shutil.rmtree(d, ignore_errors=True)
        record["checkpointed"][f"S{S}"] = {
            "sec": dt_ck, "rows_per_sec": total_rows / dt_ck,
            "vs_inmem": dt_ck / dt}
        rows.append((f"exchange/spill_gather/S{S}", dt_ck * 1e6,
                     f"{total_rows / dt_ck / 1e6:.2f} Mrows/s "
                     f"{dt_ck / dt:.1f}x inmem"))

    shard0 = _make_shards(1, rows_per_shard)[0]
    _, dt_pack = _time_once(lambda: ex.pack_shard(shard0))
    packed = ex.pack_shard(shard0)
    _, dt_unpack = _time_once(lambda: ex.unpack_shard(packed))
    record["wire"] = {"pack_sec": dt_pack, "unpack_sec": dt_unpack,
                      "rows": len(shard0.interner)}
    rows.append(("exchange/pack", dt_pack * 1e6,
                 f"{len(shard0.interner)} rows"))
    rows.append(("exchange/unpack", dt_unpack * 1e6,
                 f"{len(shard0.interner)} rows"))

    _JSON_PATH.write_text(json.dumps(record, indent=2))
    if verbose:
        for nm, us, d_ in rows:
            print(f"{nm:40s} {us:12.1f}us {d_}")
        print(f"wrote {_JSON_PATH}")
    return [csv_row(nm, us, d_) for nm, us, d_ in rows]


if __name__ == "__main__":
    run()
