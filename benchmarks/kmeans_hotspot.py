"""Paper §7.1 / Table 2: hotspot energy optimization of the dominant region.

The paper profiles k-means, finds one basic block (euclidean-distance
loop) taking 56% of runtime, and tunes {threads × compiler hints} per
objective. TPU analogue: ALEA profiles a qwen3-1.7b train step, identifies
the dominant region (attention score compute), and tunes:

  * chips (1/2/4/8 — the thread-count/concurrency-throttling analogue),
  * impl hints: naive attention vs Pallas flash attention (the unroll/
    vectorize analogue: ~2× fewer FLOPs via causal block skip, ~S× less
    HBM traffic via no materialized scores).

Reported exactly like Table 2: time / energy / power / ED / ED² per
(chips × impl), for the dominant region and the whole program; then the
whole-program saving of the energy-optimal configuration vs the
max-performance baseline (paper: 37% at a 20% performance loss).
"""

from __future__ import annotations

from repro.configs.base import SHAPES
from repro.configs.registry import get_config
from repro.core import (EnergyProfiler, ImplVariant, ground_truth,
                        synthesize)
from repro.core.energy_opt import evaluate
from repro.core.power_model import PowerModel
from repro.roofline.cost_model import step_region_costs

# TPU-scale concurrency-throttling range: the paper's 1-8 threads saturate
# one socket's DRAM; a TP/DP submesh saturates ICI at tens of chips, so the
# energy U-shape lives at 4-64 chips here.
CHIPS = (4, 8, 16, 32, 64)
IMPLS = {
    "naive": ImplVariant("naive", flop_mult=1.0, byte_mult=1.0,
                         efficiency=0.55),
    "flash": ImplVariant("flash", flop_mult=0.55, byte_mult=0.10,
                         efficiency=0.85),
}


def run(verbose: bool = True) -> list[str]:
    cfg = get_config("qwen3-1.7b")
    shape = SHAPES["train_4k"]
    costs = step_region_costs(cfg, shape, chips=8)
    pm = PowerModel()
    rows = []

    # 1) ALEA finds the hotspot.
    tl = synthesize(costs, steps=200, chips=8, seed=0)
    prof = EnergyProfiler(period=10e-3)
    est = prof.profile_timeline(tl, sensor="rapl")
    hot = est.dominant(1)[0]
    frac = hot.t_hat / est.t_exec
    if verbose:
        print(f"hotspot: {hot.name} ({frac*100:.0f}% of step time, "
              f"{hot.pow_hat:.0f}W)")
    rows.append(("kmeans_hotspot/hotspot", 0.0,
                 f"{hot.name} frac={frac*100:.0f}% pow={hot.pow_hat:.0f}W"))

    hot_cost = next(c for c in costs if c.name == hot.name)
    other_costs = [c for c in costs if c.name != hot.name]

    # 2) Table-2 grid for the dominant region and the whole program.
    table = {}
    for impl_name, impl in IMPLS.items():
        for chips in CHIPS:
            t_hot, e_hot = evaluate(hot_cost, freq_scale=1.0, chips=chips,
                                    impl=impl, model=pm)
            t_rest = e_rest = 0.0
            for c in other_costs:
                t, e = evaluate(c, freq_scale=1.0, chips=chips,
                                impl=ImplVariant("default"), model=pm)
                t_rest += t
                e_rest += e
            prog_t, prog_e = t_hot + t_rest, e_hot + e_rest
            table[(impl_name, chips)] = (t_hot, e_hot, prog_t, prog_e)
            d = (f"bb: t={t_hot*1e3:.1f}ms E={e_hot:.1f}J "
                 f"P={e_hot/t_hot/chips:.0f}W ED={e_hot*t_hot:.2f} "
                 f"ED2={e_hot*t_hot*t_hot:.3f} | prog: t={prog_t*1e3:.1f}ms "
                 f"E={prog_e:.1f}J")
            rows.append((f"kmeans_hotspot/{impl_name}/{chips}chips",
                         t_hot * 1e6, d))
            if verbose:
                print(f"{impl_name:6s} chips={chips}  {d}")

    # 3) Optima per objective (paper: they differ).
    def best(key):
        return min(table, key=lambda k: key(*table[k]))

    b_time = best(lambda th, eh, pt, pe: pt)
    b_ed = best(lambda th, eh, pt, pe: pe * pt)
    base_t, base_e = table[b_time][2], table[b_time][3]
    # Energy optimum under a bounded slowdown (the paper's energy-optimal
    # config costs 20% performance; unbounded throttling is uninteresting).
    feasible = {k: v for k, v in table.items() if v[2] <= 2.0 * base_t}
    b_energy = min(feasible, key=lambda k: feasible[k][3])
    opt_t, opt_e = table[b_energy][2], table[b_energy][3]
    saving = 1 - opt_e / base_e
    slowdown = opt_t / base_t - 1
    summary = (f"time-opt={b_time} energy-opt={b_energy} ED-opt={b_ed}; "
               f"energy-optimal saves {saving*100:.0f}% energy at "
               f"{slowdown*100:+.0f}% time vs max-perf baseline "
               f"(paper: 37% at +20%)")
    rows.append(("kmeans_hotspot/summary", 0.0, summary))
    if verbose:
        print(summary)
    return [f"{n},{us:.1f},{d}" for n, us, d in rows]


if __name__ == "__main__":
    run()
