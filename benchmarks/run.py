"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Roofline rows (§Dry-run
artifacts) are generated separately by repro.launch.dryrun (device-count
env must be set before jax init) and aggregated in EXPERIMENTS.md.
"""

from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (aggregation, domains, exchange, kernels,
                            kmeans_hotspot, memory_power, ocean_finegrain,
                            pipeline, sampling_period, serve_recovery,
                            serve_spec, sketch, spill, validation)
    mods = [
        ("sampling_period (Fig 4/5)", sampling_period),
        ("validation (Fig 6 / §5)", validation),
        ("memory_power (Table 1, Fig 8/9, §6)", memory_power),
        ("kmeans_hotspot (Table 2, §7.1)", kmeans_hotspot),
        ("ocean_finegrain (Table 3, §7.2)", ocean_finegrain),
        ("kernels (Pallas microbench)", kernels),
        ("aggregation (streaming engine)", aggregation),
        ("exchange (cross-host shard reduction)", exchange),
        ("spill (full vs incremental delta publishing)", spill),
        ("sketch (bounded heavy-hitters memory sweep)", sketch),
        ("pipeline (device-resident fused sampling)", pipeline),
        ("domains (multi-rail attribution, D=1 vs D=3)", domains),
        ("serve_recovery (shed rate, snapshot + restore cost)",
         serve_recovery),
        ("serve_spec (speculative accepted-tokens-per-joule sweep)",
         serve_spec),
    ]
    all_rows = ["name,us_per_call,derived"]
    for title, mod in mods:
        print(f"\n##### {title}", file=sys.stderr)
        all_rows += mod.run(verbose=False)
    print("\n".join(all_rows))


if __name__ == "__main__":
    main()
