"""Spill-path benchmark: full re-publish vs incremental delta spills.

The serving accountant publishes its shard every epoch; the cost that
matters for always-on fleet monitoring is *bytes written per epoch* at
steady state (ALEA's ~1% overhead budget — see PAPERS: "What Is the Cost
of Energy Monitoring?"). This benchmark drives one host through
``ALEA_BENCH_SPILL_EPOCHS`` (default 10³) epochs over a combination
table of ``ALEA_BENCH_SPILL_ROWS`` (default 10⁴) distinct rows, with a
small per-epoch sample batch (the steady-state regime: most rows
untouched each epoch), in both modes:

* ``full`` — ``spill_shard`` rewrites the whole table every epoch;
* ``delta`` — ``ShardSpiller`` publishes changed rows only, compacting
  every 64 epochs.

Emits CSV rows plus ``BENCH_spill.json`` with bytes/epoch (mean and
delta-steady-state), wall time/epoch, and the full/delta ratios.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import csv_row
from repro.core import exchange as ex
from repro.core.streaming import StreamingCombinationAggregator

_JSON_PATH = pathlib.Path(__file__).with_name("BENCH_spill.json")

COMPACT_EVERY = 64


def _dir_bytes(d: str) -> int:
    total = 0
    for root, _dirs, files in os.walk(d):
        for f in files:
            total += os.path.getsize(os.path.join(root, f))
    return total


def _seed_aggregator(rows: int) -> StreamingCombinationAggregator:
    """An aggregator pre-populated with exactly ``rows`` distinct
    combination rows (the long-running-host steady state)."""
    side = int(np.ceil(np.sqrt(rows)))
    a, b = np.meshgrid(np.arange(side), np.arange(side))
    mat = np.stack([a.ravel(), b.ravel()], 1)[:rows].astype(np.int64)
    agg = StreamingCombinationAggregator()
    agg.update(mat, np.full(rows, 100.0))
    return agg


def _run_mode(mode: str, epochs: int, rows: int, batch: int, seed: int):
    """Returns (bytes_per_epoch list, total_seconds)."""
    rng = np.random.default_rng(seed)
    agg = _seed_aggregator(rows)
    side = int(np.ceil(np.sqrt(rows)))
    d = tempfile.mkdtemp(prefix=f"bench_spill_{mode}_")
    per_epoch = []
    try:
        spiller = ex.ShardSpiller(d, 0, mode=mode,
                                  compact_every=COMPACT_EVERY)
        t0 = time.perf_counter()
        for e in range(1, epochs + 1):
            # steady state: a small batch touches ~batch distinct rows
            mat = rng.integers(0, side, (batch, 2)).astype(np.int64)
            pows = rng.integers(50 * 64, 200 * 64, batch) / 64.0
            agg.update(mat, pows)
            out = spiller.spill(agg, e)
            per_epoch.append(_dir_bytes(out))
        total = time.perf_counter() - t0
    finally:
        shutil.rmtree(d, ignore_errors=True)
    return per_epoch, total


def run(verbose: bool = True) -> list[str]:
    epochs = int(os.environ.get("ALEA_BENCH_SPILL_EPOCHS", 1000))
    rows = int(os.environ.get("ALEA_BENCH_SPILL_ROWS", 10_000))
    batch = int(os.environ.get("ALEA_BENCH_SPILL_BATCH", 256))

    record: dict = {"epochs": epochs, "rows": rows,
                    "batch_per_epoch": batch,
                    "compact_every": COMPACT_EVERY}
    out_rows: list[tuple[str, float, str]] = []
    stats = {}
    for mode in ("full", "delta"):
        per_epoch, total = _run_mode(mode, epochs, rows, batch, seed=0)
        arr = np.asarray(per_epoch, np.float64)
        # delta steady state = the non-compaction epochs (bases recur
        # every COMPACT_EVERY and are amortized into the mean)
        steady = float(np.median(arr))
        stats[mode] = {"bytes_per_epoch_mean": float(arr.mean()),
                       "bytes_per_epoch_steady": steady,
                       "bytes_total": float(arr.sum()),
                       "sec_per_epoch": total / epochs,
                       "sec_total": total}
        out_rows.append((f"spill/{mode}", total / epochs * 1e6,
                         f"{arr.mean() / 1024:.1f} KiB/epoch mean "
                         f"{steady / 1024:.1f} KiB steady"))
    record["full"] = stats["full"]
    record["delta"] = stats["delta"]
    record["ratio_bytes_mean"] = (stats["full"]["bytes_per_epoch_mean"]
                                  / stats["delta"]["bytes_per_epoch_mean"])
    record["ratio_bytes_steady_state"] = (
        stats["full"]["bytes_per_epoch_steady"]
        / stats["delta"]["bytes_per_epoch_steady"])
    record["ratio_sec"] = (stats["full"]["sec_per_epoch"]
                           / stats["delta"]["sec_per_epoch"])
    out_rows.append(("spill/ratio_steady", 0.0,
                     f"{record['ratio_bytes_steady_state']:.1f}x fewer "
                     f"bytes/epoch (delta vs full)"))

    _JSON_PATH.write_text(json.dumps(record, indent=2))
    if verbose:
        for nm, us, d_ in out_rows:
            print(f"{nm:40s} {us:12.1f}us {d_}")
        print(f"wrote {_JSON_PATH}")
    return [csv_row(nm, us, d_) for nm, us, d_ in out_rows]


if __name__ == "__main__":
    run()
