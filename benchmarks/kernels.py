"""Pallas kernel microbenchmarks (interpret mode on CPU — correctness-path
timings for CI regression; real TPU numbers come from the roofline model).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import timed
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.sample_attr.ops import sample_attr


def run(verbose: bool = True) -> list[str]:
    rng = np.random.default_rng(0)
    rows = []

    ids = jnp.asarray(rng.integers(0, 64, 65536).astype(np.int32))
    pw = jnp.asarray(rng.random(65536).astype(np.float32))
    _, us = timed(lambda: sample_attr(ids, pw, 64)[0].block_until_ready())
    rows.append(("kernels/sample_attr/64k_samples", us,
                 "interpret=cpu 64 regions"))

    q = jnp.asarray(rng.standard_normal((1, 2, 512, 64)), jnp.float32)
    _, us = timed(lambda: flash_attention(
        q, q, q, causal=True, block_q=128, block_kv=128,
        interpret=True).block_until_ready())
    rows.append(("kernels/flash_attention/512seq", us, "interpret=cpu"))

    x = jnp.asarray(rng.standard_normal((2048, 1024)), jnp.float32)
    s = jnp.ones((1024,), jnp.float32)
    _, us = timed(lambda: rmsnorm(x, s, interpret=True).block_until_ready())
    rows.append(("kernels/rmsnorm/2048x1024", us, "interpret=cpu"))

    if verbose:
        for n, us, d in rows:
            print(f"{n:40s} {us:10.1f}us {d}")
    return [f"{n},{us:.1f},{d}" for n, us, d in rows]


if __name__ == "__main__":
    run()
