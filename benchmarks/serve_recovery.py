"""Serving robustness benchmark: overload shed-rate, snapshot overhead,
restore-replay cost.

Three costs bound how cheaply the serving seam's failure model can be
kept always-on (the same budget argument the spill benchmark makes for
the profiling fleet):

* **overload shed-rate** — flood a B-slot engine with 4×B requests
  through a bounded queue and measure the fraction shed by the ladder
  versus completed (and that every submitted request is accounted for);
* **snapshot overhead** — wall cost of one durable `snap_%09d` publish
  (manifest+CRC+rename) relative to one decode step, i.e. what a
  snapshot-every-k-steps cadence adds to serving latency;
* **restore-replay cost** — wall cost of `restore_engine` replaying the
  prompt+generated prefixes, the price of bit-exactness paid once per
  crash (scales with live tokens at kill time, not with run length).

Run at B ∈ {8, 32}; emits CSV rows plus ``BENCH_serve_recovery.json``.
"""

from __future__ import annotations

import json
import pathlib
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import csv_row

_JSON_PATH = pathlib.Path(__file__).with_name("BENCH_serve_recovery.json")

BATCHES = (8, 32)
MAX_NEW = 6
PROMPT_LEN = 5


def _engine(cfg, params, B, queue_capacity=None):
    from repro.serve.engine import Engine, ServeConfig
    from repro.serve.scheduler import OverloadPolicy, ServeScheduler
    scfg = ServeConfig(max_batch=B, max_len=64, eos_token=-1)
    sched = None
    if queue_capacity is not None:
        sched = ServeScheduler(OverloadPolicy(
            queue_capacity=queue_capacity,
            backpressure_at=max(1, queue_capacity // 4),
            shed_at=max(1, queue_capacity // 2),
            widen_at=queue_capacity))
    return Engine(cfg, params, scfg, scheduler=sched)


def _requests(cfg, n, seed=0):
    from repro.serve.engine import Request
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(1, cfg.vocab_size, PROMPT_LEN)
                    .astype(np.int32), max_new_tokens=MAX_NEW,
                    priority=i % 3) for i in range(n)]


def _bench_batch(cfg, params, B):
    from repro.serve.engine import Engine
    from repro.serve.recovery import restore_engine
    from repro.serve.scheduler import AdmissionError

    out = {}

    # -- overload shed-rate: 4B requests into a B-deep queue ----------------
    eng = _engine(cfg, params, B, queue_capacity=B)
    submitted = rejected = 0
    for r in _requests(cfg, 4 * B):
        try:
            eng.submit(r)
            submitted += 1
        except AdmissionError:
            rejected += 1
    steps = 0
    t0 = time.perf_counter()
    while (any(s is not None for s in eng.slot_req)
           or len(eng.scheduler.queue)):
        eng.step()
        steps += 1
    drain_s = time.perf_counter() - t0
    rep = eng.report
    total = 4 * B
    out["shed_rate"] = rep.shed / total
    out["completed"] = rep.completed
    out["accounted"] = rep.completed + rep.shed
    out["overload_steps"] = steps
    out["overload_drain_s"] = drain_s

    # -- snapshot overhead vs decode step -----------------------------------
    eng = _engine(cfg, params, B)
    for r in _requests(cfg, B, seed=1):
        eng.add_request(r)
    t0 = time.perf_counter()
    eng.step()
    step_s = time.perf_counter() - t0
    td = tempfile.mkdtemp(prefix="serve_snap_")
    try:
        t0 = time.perf_counter()
        eng.snapshot(td)
        snap_s = time.perf_counter() - t0
        live_tokens = int(sum(len(r.prompt) + len(r.out_tokens)
                              for r in eng.slot_req if r is not None))

        # -- restore-replay cost -------------------------------------------
        t0 = time.perf_counter()
        restored = restore_engine(cfg, params, eng.scfg, td)
        restore_s = time.perf_counter() - t0
        assert restored.step_count == eng.step_count
    finally:
        shutil.rmtree(td, ignore_errors=True)
    out["decode_step_us"] = step_s * 1e6
    out["snapshot_us"] = snap_s * 1e6
    out["snapshot_vs_step"] = snap_s / step_s
    out["restore_us"] = restore_s * 1e6
    out["live_tokens_at_snapshot"] = live_tokens
    out["restore_us_per_token"] = restore_s * 1e6 / max(live_tokens, 1)
    return out


def run(verbose: bool = True) -> list[str]:
    import jax
    from repro.configs.registry import get_config
    from repro.models import model as M

    cfg = get_config("qwen3-1.7b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    rows: list[str] = []
    results: dict[str, dict] = {}
    for B in BATCHES:
        r = _bench_batch(cfg, params, B)
        results[f"B{B}"] = r
        rows.append(csv_row(
            f"serve_overload_shed_B{B}", r["overload_drain_s"] * 1e6,
            f"shed_rate={r['shed_rate']:.3f} "
            f"completed={r['completed']} accounted={r['accounted']}"))
        rows.append(csv_row(
            f"serve_snapshot_B{B}", r["snapshot_us"],
            f"x{r['snapshot_vs_step']:.2f}_decode_step"))
        rows.append(csv_row(
            f"serve_restore_B{B}", r["restore_us"],
            f"{r['restore_us_per_token']:.1f}us_per_live_token"))
    _JSON_PATH.write_text(json.dumps(
        {"batches": list(BATCHES), "max_new_tokens": MAX_NEW,
         "prompt_len": PROMPT_LEN, "results": results}, indent=2))
    if verbose:
        print("\n".join(rows))
    return rows


if __name__ == "__main__":
    run()
