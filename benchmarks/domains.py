"""Multi-domain (power-rail) attribution benchmark.

Measures the cost of the domain axis through the fused device pipeline:
the same profiling run at D = 1 (scalar, the pre-rail graph) and D = 3
(package/HBM/ICI rails — per-rail sensor emulation vmapped over the
domain axis plus the dedicated total channel in the carry). Reported as
samples/sec for the single-worker region path and the W=4 combination
path; the acceptance gate is D=3 staying within 2× of D=1 (the rail
bank triples the energy-interpolation work but shares the interval
lookup, time generation and table search, so the slowdown must stay far
below 3×). Also reports the per-domain energy split of the §6
memory_power-style workload, reproduced *directly* from rail
attribution rather than inferred from activity coefficients. Emits the
usual CSV rows plus ``BENCH_domains.json``.
"""

from __future__ import annotations

import json
import os
import pathlib
import time

import numpy as np

from benchmarks.common import csv_row
from repro.core.sensors import RaplTraceSensor
from repro.core.timeline import RegionCost, ground_truth, synthesize

_JSON_PATH = pathlib.Path(__file__).with_name("BENCH_domains.json")
PERIOD = 1e-3
JITTER = 200e-6
CHUNK = 16384
SEED = 11

# §6-flavoured region mix: compute-bound, memory-bound, link-bound.
COSTS = [
    RegionCost("mxu_gemm", flops=3e12, hbm_bytes=1.2e9, invocations=4),
    RegionCost("hbm_stream", flops=4e10, hbm_bytes=6.4e9, invocations=3),
    RegionCost("allreduce", flops=2e9, hbm_bytes=2e8, ici_bytes=8e8,
               invocations=2),
    RegionCost("host_wait", flops=1e9, hbm_bytes=1e7, invocations=1),
]


def _timelines(n_samples: int, domains: bool, w: int = 1):
    t_end = n_samples * PERIOD
    # scale steps so the horizon covers the target sample volume
    one = synthesize(COSTS, steps=1, seed=SEED, domains=domains)
    steps = max(int(t_end / one.t_exec) + 1, 1)
    return [synthesize(COSTS, steps=steps, seed=SEED + i, domains=domains)
            for i in range(w)]


def _fused_run(tls):
    from repro.core import device_pipeline as dp
    dtl = dp.DeviceTimeline.from_timelines(tls)
    spec = RaplTraceSensor.make_spec(domains=dtl.domains)
    if len(tls) == 1:
        res = dp.run_region_pipeline(dtl, spec, period=PERIOD,
                                     jitter=JITTER, seed=SEED,
                                     chunk_size=CHUNK)
        return res.n
    agg, n = dp.run_combo_pipeline(dtl, spec, period=PERIOD,
                                   jitter=JITTER, seed=SEED,
                                   chunk_size=CHUNK)
    return n


def run(verbose: bool = True) -> list[str]:
    n_target = int(os.environ.get("ALEA_BENCH_N", 200_000))
    rows: list[tuple[str, float, str]] = []
    record: dict = {"n_samples_target": n_target, "period": PERIOD,
                    "chunk": CHUNK, "sensor": "rapl",
                    "note": "fused timings exclude compilation "
                            "(one warmup pass)",
                    "configs": {}}

    rates: dict[tuple[int, int], float] = {}
    for w in (1, 4):
        for d, use_domains in ((1, False), (3, True)):
            tls = _timelines(n_target // w, use_domains, w)
            _fused_run(tls)                  # warmup: compile + tables
            t0 = time.perf_counter()
            n = _fused_run(tls)
            dt = time.perf_counter() - t0
            rate = n / dt
            rates[(w, d)] = rate
            record["configs"][f"W{w}_D{d}"] = {
                "n_samples": n, "sec": dt, "samples_per_sec": rate}
            rows.append((f"domains/fused/W{w}_D{d}", dt * 1e6,
                         f"{rate / 1e6:.2f} Msamples/s"))
    for w in (1, 4):
        ratio = rates[(w, 1)] / rates[(w, 3)]
        record["configs"][f"W{w}_D3"]["slowdown_vs_d1"] = ratio
        rows.append((f"domains/slowdown/W{w}", 0.0,
                     f"D3 {ratio:.2f}x slower than D1 (gate: < 2x)"))

    # §6 compute-vs-memory split, measured directly from rail
    # attribution (no EPI/activity inference) — cf. memory_power.py.
    tl = _timelines(n_target, True)[0]
    from repro.core.profiler import EnergyProfiler
    est = EnergyProfiler(period=PERIOD, jitter=JITTER, seed=SEED) \
        .profile_timeline_streaming(tl, sensor="rapl", chunk_size=CHUNK)
    truth = ground_truth(tl)
    split = {}
    for name in ("mxu_gemm", "hbm_stream"):
        r = next(r for r in est.regions if r.name == name)
        e = r.energy_by_domain()
        gt = truth[name]["energy_rails"]
        split[name] = {
            "measured": e,
            "truth": gt,
            "hbm_share": e["hbm"] / r.e_hat,
        }
        rows.append((f"domains/split/{name}", 0.0,
                     f"hbm {e['hbm']:.2f}J/{r.e_hat:.2f}J "
                     f"({e['hbm'] / r.e_hat * 100:.0f}%) "
                     f"truth {gt['hbm']:.2f}J"))
    record["memory_power_split"] = split

    _JSON_PATH.write_text(json.dumps(record, indent=2))
    if verbose:
        for nm, us, d in rows:
            print(f"{nm:32s} {us:14.1f}us {d}")
        print(f"wrote {_JSON_PATH}")
    return [csv_row(nm, us, d) for nm, us, d in rows]


if __name__ == "__main__":
    run()
