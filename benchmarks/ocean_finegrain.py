"""Paper §7.2 / Table 3: fine-grain per-region energy optimization.

The paper tunes six dominant ocean_cp basic blocks independently over
{frequency × threads × compiler optimizations} and shows (a) the optimal
knobs DIFFER per block, (b) whole-program energy drops 33% vs the
max-performance baseline. TPU analogue: the six dominant regions of a
zamba2-1.2b train step, tuned over {DVFS scale × chips × impl variants}.
"""

from __future__ import annotations

from repro.configs.base import SHAPES
from repro.configs.registry import get_config
from repro.core import EnergyProfiler, ImplVariant, KnobSpace, synthesize
from repro.core.energy_opt import baseline_plan, optimize_regions
from repro.core.power_model import PowerModel
from repro.roofline.cost_model import step_region_costs

IMPL_SPACE = {
    # attention regions get the flash variant; ssm scan gets a fused-chunk
    # variant; everything else chooses remat-off/on (bytes vs flops trade).
    "attn_score": [ImplVariant("default"),
                   ImplVariant("flash", flop_mult=0.55, byte_mult=0.10)],
    "ssm_scan": [ImplVariant("default"),
                 ImplVariant("fused_chunk", byte_mult=0.5, efficiency=0.9)],
    "ffn": [ImplVariant("default"),
            ImplVariant("no_remat", flop_mult=0.67, byte_mult=1.3)],
}


def run(verbose: bool = True) -> list[str]:
    cfg = get_config("zamba2-1.2b")
    shape = SHAPES["train_4k"]
    base_chips = 8
    costs = step_region_costs(cfg, shape, chips=base_chips)
    pm = PowerModel()
    rows = []

    # ALEA surfaces the dominant regions.
    tl = synthesize(costs, steps=150, chips=base_chips, seed=0)
    prof = EnergyProfiler(period=10e-3)
    est = prof.profile_timeline(tl, sensor="rapl")
    top = [r.name for r in est.dominant(6)]
    top_costs = [c for c in costs if c.name in top]
    if verbose:
        print("dominant regions:", ", ".join(top))

    space = KnobSpace(freq_scales=(1.0, 0.94, 0.88, 0.81),
                      chip_counts=(1, 2, 4, 8))
    base = baseline_plan(top_costs, chips=base_chips, model=pm)
    opt = optimize_regions(top_costs, space, objective="energy", model=pm,
                           impl_space=IMPL_SPACE, baseline_chips=base_chips,
                           max_slowdown=2.0)

    for b, o in zip(base.plans, opt.plans):
        save = 1 - o.energy / b.energy
        d = (f"base: t={b.time*1e3:.2f}ms E={b.energy:.2f}J → opt: "
             f"t={o.time*1e3:.2f}ms E={o.energy:.2f}J "
             f"[freq={o.freq_scale:.2f} chips={o.chips} impl={o.impl}] "
             f"save={save*100:.0f}%")
        rows.append((f"ocean_finegrain/{b.region}", b.time * 1e6, d))
        if verbose:
            print(f"{b.region:14s} {d}")

    saving = 1 - opt.energy / base.energy
    distinct = len({(p.freq_scale, p.chips, p.impl) for p in opt.plans})
    summary = (f"whole-program energy saving {saving*100:.0f}% "
               f"(paper: 33%); {distinct} distinct per-region knob settings "
               f"across {len(opt.plans)} regions — fine-grain attribution "
               f"is what exposes them")
    rows.append(("ocean_finegrain/summary", 0.0, summary))
    if verbose:
        print(summary)
    return [f"{n},{us:.1f},{d}" for n, us, d in rows]


if __name__ == "__main__":
    run()
