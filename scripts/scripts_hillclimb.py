"""§Perf hillclimb driver: lower variant configs for the three chosen
cells and print term deltas vs the sweep baselines.

    PYTHONPATH=src python scripts_hillclimb.py <cell> <variant>
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
import sys

from repro.configs.registry import get_config
from repro.launch.dryrun import lower_cell

CELLS = {
    "moe-train-bf16g": ("qwen3-moe-30b-a3b", "train_4k",
                        dict(bf16_gather=True)),
    "sc2-train-bf16g": ("starcoder2-15b", "train_4k",
                        dict(bf16_gather=True)),
    "yi-decode-grouped": ("yi-6b", "decode_32k",
                          dict(decode_grouped=True)),
    "yi-decode-grouped-f8": ("yi-6b", "decode_32k",
                             dict(decode_grouped=True,
                                  kv_cache_dtype="float8_e4m3fn")),
    "moe-train-nosp": ("qwen3-moe-30b-a3b", "train_4k", dict()),
    "xlstm-train-bf16g": ("xlstm-125m", "train_4k", dict(bf16_gather=True)),
    "stablelm-decode-f8": ("stablelm-3b", "decode_32k",
                           dict(kv_cache_dtype="float8_e4m3fn")),
    "yi-decode-f8": ("yi-6b", "decode_32k",
                     dict(decode_grouped=True,
                          kv_cache_dtype="float8_e4m3fn")),
    "xlstm-train-nosp": ("xlstm-125m", "train_4k", dict(disable_sp=True)),
    "moe-train-bf16g-nosp": ("qwen3-moe-30b-a3b", "train_4k",
                             dict(bf16_gather=True, disable_sp=True)),
}

name = sys.argv[1]
arch, shape, kw = CELLS[name]
cfg = get_config(arch).replace(**kw)
row, _ = lower_cell(arch, shape, multi_pod=False, cfg_override=cfg)
out = f"results/dryrun/VARIANT__{name}.json"
with open(out, "w") as f:
    json.dump(row, f, indent=1, default=str)
print(f"[VARIANT {name}] dominant={row['dominant']} "
      f"frac={row['roofline_fraction']:.3f}")
print(f"  compute {row['t_compute_s']*1e3:.2f}ms "
      f"memory {row['t_memory_s']*1e3:.2f}ms "
      f"collective {row['t_collective_s']*1e3:.2f}ms")
print("  collectives:", {k: round(v/2**30, 2)
                         for k, v in row["collectives"].items() if v})
