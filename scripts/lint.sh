#!/usr/bin/env bash
# Fast syntax gate: fail on syntax-level breakage in seconds, before the
# ~3-minute tier-1 pytest suite spins up.
#
#   scripts/lint.sh
#
# 1. python -m compileall — byte-compiles every file under src/ tests/
#    benchmarks/ scripts/ examples/ (catches SyntaxError, including ones
#    pytest would only hit on import of a late-collected module).
# 2. pyflakes (if installed) — undefined names, unused/shadowed imports,
#    f-string mistakes. Skipped with a notice when unavailable: the
#    container image does not bake it in, and this gate must not
#    install anything.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== compileall =="
python -m compileall -q -f src tests benchmarks scripts examples

if python -c "import pyflakes" 2>/dev/null; then
    echo "== pyflakes =="
    python -m pyflakes src tests benchmarks scripts examples
else
    echo "== pyflakes not installed; skipping (compileall gate only) =="
fi

echo "lint OK"
