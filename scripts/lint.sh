#!/usr/bin/env bash
# Fast syntax + contract gate: fail on syntax-level breakage and contract
# violations in seconds-to-a-minute, before the ~3-minute tier-1 pytest
# suite spins up.
#
#   scripts/lint.sh
#
# 1. python -m compileall — byte-compiles every file under src/ tests/
#    benchmarks/ scripts/ examples/ (catches SyntaxError, including ones
#    pytest would only hit on import of a late-collected module).
# 2. pyflakes — undefined names, unused/shadowed imports, f-string
#    mistakes. In CI (CI=true, where requirements-dev.txt is installed)
#    a missing pyflakes is a hard failure — the undefined-name gate must
#    not silently disappear from the pipeline. Locally it is skipped
#    with a notice: the container image does not bake it in, and this
#    gate must not install anything.
# 3. contract audit — `python -m repro.analysis --check`: AST contract
#    passes (determinism hygiene, typed spill errors, silent excepts,
#    fault-site registry, x64 scoping) ratcheted by
#    src/repro/analysis/baseline.json, plus jaxpr hot-path audits (f64
#    inventory, donation aliasing, host callbacks) ratcheted by
#    src/repro/analysis/x64_budget.json.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== compileall =="
python -m compileall -q -f src tests benchmarks scripts examples

if python -c "import pyflakes" 2>/dev/null; then
    echo "== pyflakes =="
    python -m pyflakes src tests benchmarks scripts examples
elif [ "${CI:-false}" = "true" ]; then
    echo "== pyflakes MISSING in CI — the undefined-name gate would" \
         "silently vanish; failing (is requirements-dev.txt installed?) =="
    exit 1
else
    echo "== pyflakes not installed; skipping (compileall gate only) =="
fi

echo "== contract audit =="
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m repro.analysis --check

echo "lint OK"
